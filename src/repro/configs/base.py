"""Config dataclasses shared by every architecture.

Every assigned architecture is a :class:`ModelConfig`; shapes are
:class:`ShapeConfig`.  ``registry`` maps ``--arch`` ids to configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (decoder-only backbone)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    n_layers: int
    d_model: int
    n_heads: int            # query heads (0 for attention-free archs)
    n_kv_heads: int         # GQA KV heads
    d_head: int
    d_ff: int
    vocab_size: int

    # --- attention details -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    rope_fraction: float = 1.0          # glm4 rotates half the head dim
    sliding_window: int = 0             # 0 = full attention (mixtral: 4096)
    # layers (indices) that use cross-attention instead of self-attention
    cross_attn_layers: Tuple[int, ...] = ()

    # --- MLP / norm flavour -------------------------------------------------
    mlp_type: str = "swiglu"            # swiglu | gelu
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0

    # --- SSM (rwkv6 / mamba2 / zamba2) --------------------------------------
    ssm_state: int = 0                  # mamba2 state size per head
    ssm_head_dim: int = 64
    ssm_expand: int = 2                 # d_inner = expand * d_model
    ssm_conv: int = 4
    # zamba2: a single shared attention block applied every k mamba layers
    shared_attn_every: int = 0

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    kv_quant: bool = False              # int8 KV cache (serving, §Perf)

    # ------------------------------------------------------------------ props
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def full_attention_only(self) -> bool:
        """True if every attention layer is dense full attention (=> long_500k skip)."""
        if self.family in ("ssm", "hybrid"):
            return False
        return self.sliding_window == 0

    def padded_heads(self, tp: int) -> int:
        """Query heads zero-padded up to a multiple of the TP degree."""
        if self.n_heads == 0:
            return 0
        return -(-self.n_heads // tp) * tp

    def expanded_kv_heads(self, tp: int) -> int:
        """KV heads replicated up to the TP degree (co-location invariant)."""
        if self.n_kv_heads == 0:
            return 0
        return max(self.n_kv_heads, min(tp, self.padded_heads(tp)))

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count (for roofline MODEL_FLOPS = 6*N*D)
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, K, dh = self.n_heads, self.n_kv_heads, self.d_head
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            d_inner = D
            tmix = 6 * D * d_inner          # r,k,v,g,w,o (approx, + small loras)
            cmix = 2 * D * F
            return L * (tmix + cmix) + emb
        attn = D * (H * dh) + 2 * D * (K * dh) + (H * dh) * D
        if self.qkv_bias:
            attn += H * dh + 2 * K * dh
        if self.is_moe:
            n_e = self.experts_per_token if active_only else self.n_experts
            mlp = n_e * 3 * D * F + D * self.n_experts  # experts + router
        elif self.mlp_type == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.family == "hybrid":
            # zamba2: mamba2 blocks + one shared attention block
            d_in = self.ssm_expand * D
            mamba = L * (D * 2 * d_in + d_in * D + d_in * (2 * self.ssm_state)
                         + d_in * self.ssm_conv + 3 * d_in)
            shared = attn + 3 * D * F
            return mamba + shared + emb
        per_layer = attn + mlp
        if self.family == "vlm":
            # cross-attention layers carry an extra KV projection pair
            per_layer_x = attn + mlp + 2 * D * (K * dh)
            n_x = len(self.cross_attn_layers)
            return (L - n_x) * per_layer + n_x * per_layer_x + emb
        return L * per_layer + emb


# ---------------------------------------------------------------------------
# Input-shape configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long-decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long-decode")


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long-decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) dry-run cell applies (long_500k policy)."""
    if shape.kind == "long-decode" and cfg.full_attention_only:
        return False, ("skipped: pure full-attention arch — 524k dense KV cache "
                       "is the quadratic blow-up long_500k excludes (DESIGN.md §5)")
    return True, ""
