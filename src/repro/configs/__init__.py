"""Architecture registry — import every config module so @register runs."""
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    cell_is_runnable,
    get_config,
    list_archs,
)

# Register all assigned architectures (+ the paper's own model).
from repro.configs import (  # noqa: F401
    glm4_9b,
    llama3_2_vision_11b,
    llama3_8b,
    mixtral_8x22b,
    mixtral_8x7b,
    musicgen_large,
    paper_gpt,
    qwen1_5_110b,
    qwen1_5_32b,
    rwkv6_7b,
    zamba2_2_7b,
)

ASSIGNED_ARCHS = (
    "qwen1.5-32b",
    "qwen1.5-110b",
    "llama3-8b",
    "glm4-9b",
    "llama-3.2-vision-11b",
    "rwkv6-7b",
    "mixtral-8x22b",
    "mixtral-8x7b",
    "musicgen-large",
    "zamba2-2.7b",
)
