"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 — partial RoPE.

[hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import ModelConfig, register


@register("glm4-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_head=128,
        d_ff=13696,
        vocab_size=151552,
        rope_theta=10_000.0,
        rope_fraction=0.5,   # GLM rotates half of each head dim
        qkv_bias=True,       # glm-4 uses attention bias on QKV
        norm_eps=1.5625e-7,
    )
