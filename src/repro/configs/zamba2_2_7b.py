"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64.

Mamba2 backbone with a single *shared* attention block applied every 6 mamba
layers (9 applications, one weight copy) — Zamba2-style hybrid.

[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        norm_eps=1e-5,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        shared_attn_every=6,
    )
