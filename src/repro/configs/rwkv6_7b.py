"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 attention-free d_ff=14336 vocab=65536.

Data-dependent decay; O(1) decode state (no K/V cache). The paper's
head+KV-cache partitioning unit does not exist here — see DESIGN.md §5
(arch-applicability): blocks become channel-head shards of the WKV state.

[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,          # wkv heads = d_model / head_dim(64)
        n_kv_heads=0,        # attention-free
        d_head=64,
        d_ff=14336,
        vocab_size=65536,
        norm_type="layernorm",
        ssm_head_dim=64,
    )
