"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, 8 experts top-2, SWA.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=16384,
        vocab_size=32768,
        rope_theta=1_000_000.0,
        norm_eps=1e-5,
        n_experts=8,
        experts_per_token=2,
        sliding_window=4096,
    )
