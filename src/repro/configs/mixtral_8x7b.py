"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2, SWA.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1_000_000.0,
        norm_eps=1e-5,
        n_experts=8,
        experts_per_token=2,
        sliding_window=4096,
    )
