"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attention image layers every 5th layer (8 of 40). The vision frontend is
a STUB per the assignment: ``input_specs()`` provides precomputed patch
embeddings (B, n_img_tokens, d_model).

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig, register

CROSS_ATTN_LAYERS = (3, 8, 13, 18, 23, 28, 33, 38)
N_IMAGE_TOKENS = 1601  # one 448x448 tile -> (448/14)^2 + 1 = 1025; HF uses 1601 w/ tiles


@register("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500_000.0,
        norm_eps=1e-5,
        cross_attn_layers=CROSS_ATTN_LAYERS,
    )
