"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

Decoder-only over EnCodec tokens; the EnCodec frontend is a STUB per the
assignment (``input_specs()`` supplies precomputed frame embeddings / codec
token ids). LayerNorm + GELU MLP (T5/BART-style decoder).

[arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,
        vocab_size=2048,
        mlp_type="gelu",
        norm_type="layernorm",
        norm_eps=1e-5,
        rope_theta=10_000.0,
    )
