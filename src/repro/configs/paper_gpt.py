"""The paper's own evaluation model: single-layer decoder, h=32, D=2048, L0=64.

"For a Large LLM model setup (h=32, D=2048), we approximate GPT-2/LLaMA
scales." — §V.B(a).  Used by the simulator benchmarks and the e2e examples.
"""
from repro.configs.base import ModelConfig, register


@register("paper-gpt")
def config() -> ModelConfig:
    return ModelConfig(
        name="paper-gpt",
        family="dense",
        n_layers=1,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,           # paper Table I uses the canonical 4*D FFN
        vocab_size=50257,    # GPT-2 vocabulary
        rope_theta=10_000.0,
        norm_eps=1e-5,
    )
