"""Training driver: data pipeline -> jit train step -> checkpointing ->
restart-on-failure; single-host CPU uses reduced configs, TPU slices use
the production mesh + shardings from the dry-run cell builder.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.api import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import HeartbeatMonitor


def reduced_for_cpu(cfg, d_model=256, n_layers=4):
    over = dict(n_layers=n_layers, d_model=d_model,
                d_ff=d_model * 4, vocab_size=4096,
                dtype="float32", param_dtype="float32")
    if cfg.n_heads:
        over.update(n_heads=8, n_kv_heads=min(8, cfg.n_kv_heads or 8),
                    d_head=d_model // 8)
    if cfg.family == "vlm":
        over["n_layers"] = 5
    if cfg.family == "hybrid":
        over.update(n_layers=4, shared_attn_every=2)
    if cfg.is_moe:
        over["n_experts"] = 4
    return cfg.with_overrides(**over)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-gpt")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_cpu(cfg, args.d_model, args.n_layers)
    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=args.steps))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    it = iter(src)
    ck = Checkpointer(args.ckpt)
    monitor = HeartbeatMonitor(jax.device_count())

    start = 0
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    if args.resume and ck.latest_step() is not None:
        start = ck.latest_step()
        state = ck.restore(start, {"params": params, "opt": opt_state,
                                   "data": src.state_dict()})
        params, opt_state = state["params"], state["opt"]
        src.load_state_dict(state["data"])
        it = iter(src)
        print(f"[train] resumed from step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        t0 = time.time()
        params, opt_state, loss = step_fn(params, opt_state, batch)
        # rpr: ignore[RPR004] -- the per-step sync is the point: dt below
        # must cover the device step for monitor.record_step telemetry
        loss = float(loss)
        dt = time.time() - t0
        monitor.record_step(0, dt)
        if (i + 1) % args.log_every == 0 or i == start:
            tps = args.batch * args.seq / dt
            print(f"[train] step {i+1:5d} loss={loss:.4f} "
                  f"{dt*1e3:7.1f} ms/step {tps:9.0f} tok/s")
        if (i + 1) % args.ckpt_every == 0:
            ck.save_async(i + 1, {"params": params, "opt": opt_state,
                                  "data": src.state_dict()})
    ck.wait()
    ck.save(args.steps, {"params": params, "opt": opt_state,
                         "data": src.state_dict()})
    print(f"[train] done; final loss={loss:.4f}; "
          f"checkpoints at {args.ckpt}")
    return loss


if __name__ == "__main__":
    main()
