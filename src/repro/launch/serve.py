"""Serving driver: batched requests through the ServingEngine with the
paper's interval controller (Algorithm 1 + migrations) in the loop.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
      --reduced --requests 8 --tokens 24 [--straggler 0]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.launch.train import reduced_for_cpu
from repro.serving.engine import make_engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--lam", type=int, default=8,
                    help="controller interval (decode steps)")
    ap.add_argument("--straggler", type=int, default=-1,
                    help="inject a 20x slowdown on this mesh slot")
    ap.add_argument("--engine", default="auto",
                    choices=("auto", "continuous", "wave"),
                    help="continuous batching (default for linear-cache "
                         "archs) or the wave baseline")
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="vary prompt lengths per request (the workload "
                         "continuous batching exists for)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="decode through the placement-driven Pallas "
                         "flash-decode kernel (auto-interpret on CPU); "
                         "greedy streams must match the jnp path")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache (continuous engine splices "
                         "quantized values + scales per slot)")
    ap.add_argument("--pipeline-k", type=int, default=1,
                    help="decode tokens in flight across slot groups "
                         "(must divide --slots)")
    ap.add_argument("--search", default="rescoring",
                    choices=("rescoring", "bottleneck"),
                    help="controller placement search: the PR-3 rescoring "
                         "path or the bottleneck-targeted search "
                         "(pipeline-k > 1)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: pooled page store + per-slot "
                         "page tables, chunked prefill (continuous "
                         "engine only); streams must match the dense "
                         "engine at the same seed")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (--paged)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_cpu(cfg)
    if args.kv_quant:
        cfg = cfg.with_overrides(kv_quant=True)
    kw = {}
    mode = args.engine
    if args.paged:
        # pages divide max_seq; the paged path is continuous-engine only
        kw.update(paged=True, page_size=args.page_size)
        mode = "continuous"
    max_seq = args.prompt_len + args.tokens + 8
    if args.paged and max_seq % args.page_size:
        max_seq += args.page_size - max_seq % args.page_size
    eng = make_engine(cfg, mode=mode, n_slots=args.slots,
                      max_seq=max_seq,
                      lam=args.lam, use_kernel=args.use_kernel,
                      pipeline_k=args.pipeline_k, search=args.search, **kw)
    print(f"[serve] engine: {type(eng).__name__}")
    if args.straggler >= 0:
        eng.net.inject_straggler(args.straggler, slowdown=20.0)
        print(f"[serve] injected straggler on slot {args.straggler}")
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        if args.mixed_lengths:
            plen = int(rng.integers(max(2, args.prompt_len // 2),
                                    args.prompt_len + 1))
        else:
            plen = args.prompt_len
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen),
                   max_new_tokens=args.tokens)
    done = eng.run()
    wall = time.time() - t0
    total_toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {total_toks} tokens in "
          f"{wall:.1f}s ({total_toks/wall:.1f} tok/s)")
    migr = sum(m["n_migrations"] for m in eng.migration_log)
    print(f"[serve] controller intervals={len(eng.migration_log)} "
          f"head-migrations={migr}")
    if hasattr(eng, "slot_busy_steps") and eng.decode_steps:
        util = eng.slot_busy_steps / (eng.decode_steps * eng.n_slots)
        print(f"[serve] slot utilization {util:.0%}, prefill buckets "
              f"{sorted(eng.prefill_buckets_used)}")
    for r in done[:3]:
        print(f"  req {r.rid}: ttft={r.t_first - r.t_submit:.2f}s "
              f"total={r.t_done - r.t_submit:.2f}s "
              f"tokens={r.out_tokens[:8]}...")
    return done


if __name__ == "__main__":
    main()
