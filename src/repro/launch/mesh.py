"""Production meshes (deliverable e).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 chips, axes
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with the extra "pod"
axis (outer data parallelism / expert parallelism).

TPU v5e constants used by the roofline analysis (benchmarks/roofline.py).
"""
from __future__ import annotations

import jax

# TPU v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def tp_degree(mesh) -> int:
    return mesh.shape["model"]


def dp_degree(mesh) -> int:
    d = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        d *= mesh.shape["pod"]
    return d


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires host-platform device override)."""
    return jax.make_mesh((data, model), ("data", "model"))
