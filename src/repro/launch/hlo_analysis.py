"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis`` provides HLO FLOPs / bytes (XLA multiplies while-loop
bodies by inferred trip counts); collective bytes are NOT included there,
so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Layer-stacked models lower ``lax.scan`` to ``while`` ops, so a naive text
scan counts per-layer collectives once: this parser builds the computation
graph, infers while trip counts from the loop condition's comparison
constant, and multiplies nested bodies accordingly. Shapes in post-SPMD
HLO are per-partition, so all byte counts are per-device.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# result-shape(s) of a collective op line, e.g.
#   %ag = bf16[8,512,128]{2,1,0} all-gather(...)
#   %ar = (f32[8]{0}, f32[8]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(r"while\(.*?\)[^\n]*?condition=%?([\w.\-]+)[^\n]*?"
                       r"body=%?([\w.\-]+)")
# computation signature line (parameter lists may contain nested tuples)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text."""
    comps: Dict[str, str] = {}
    matches = list(_COMP_HDR_RE.finditer(hlo))
    for i, m in enumerate(matches):
        start = m.start()
        end = matches[i + 1].start() if i + 1 < len(matches) else len(hlo)
        comps[m.group(1)] = hlo[start:end]
    return comps


def _trip_count(cond_text: str) -> int:
    """Trip count from the loop condition: the comparison constant.
    Falls back to 1 (conservative) if no constant is found."""
    consts = [int(c) for c in
              re.findall(r"constant\((\d+)\)", cond_text)]
    plausible = [c for c in consts if 1 <= c <= 100000]
    return max(plausible) if plausible else 1


def _direct_collectives(comp_text: str) -> Dict[str, float]:
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in comp_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # async pair counted at -start
        shapes_str, kind = m.group(1), m.group(2)
        total = sum(_shape_bytes(d, s)
                    for d, s in _SHAPE_RE.findall(shapes_str))
        if "promoted" in line:
            # CPU backend promotes bf16 reductions to f32
            # (to_apply=%add..._promoted); TPU reduces natively in bf16 —
            # count at the pre-promotion width.
            total *= 0.5
        out[kind] += float(total)
        counts[kind] += 1
    return out, counts  # type: ignore[return-value]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind collective bytes (per device), while-loop trip counts
    applied. Also returns op counts under key "_counts"."""
    comps = _split_computations(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: flat scan
        out, counts = _direct_collectives(hlo_text)
        out["_counts"] = counts  # type: ignore[assignment]
        return out

    memo: Dict[str, Tuple[Dict[str, float], Dict[str, int]]] = {}

    def visit(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        if name not in comps or depth > 20:
            z = ({k: 0.0 for k in COLLECTIVES}, {k: 0 for k in COLLECTIVES})
            return z
        text = comps[name]
        bytes_d, counts_d = _direct_collectives(text)
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            b_b, b_c = visit(body, depth + 1)
            for k in COLLECTIVES:
                bytes_d[k] += trips * b_b[k]
                counts_d[k] += trips * b_c[k]
        # non-while calls (call/conditional bodies) counted once
        for cm in re.finditer(r"(?:call|to_apply)=%?([\w.\-]+)", text):
            sub = cm.group(1)
            if sub in (name,):
                continue
            b_b, b_c = visit(sub, depth + 1)
            for k in COLLECTIVES:
                bytes_d[k] += b_b[k]
                counts_d[k] += b_c[k]
        memo[name] = (bytes_d, counts_d)
        return memo[name]

    bytes_d, counts_d = visit(entry)
    out: Dict[str, float] = dict(bytes_d)
    out["_counts"] = counts_d  # type: ignore[assignment]
    return out


def total_collective_bytes(hlo_text: str) -> float:
    d = collective_bytes(hlo_text)
    return float(sum(v for k, v in d.items() if not k.startswith("_")))


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Trip-aware FLOP / HBM-byte analysis.
#
# XLA's CPU cost_analysis counts while bodies ONCE (verified empirically):
# a scan of 10 matmuls reports 1 matmul of FLOPs.  Layer-scanned models make
# that useless for rooflines, so we derive both terms from the optimized
# HLO ourselves, multiplying loop bodies by inferred trip counts:
#
#  dot FLOPs  = 2 * prod(result dims) * prod(lhs contracting dims)
#               (elementwise FLOPs excluded — consistent with MODEL_FLOPS)
#  HBM bytes  = sum over scope-level ops of operand+result bytes, i.e. the
#               post-fusion kernel-boundary traffic model; free ops
#               (tuple/gte/param/constant/bitcast/while/reshape) excluded;
#               dynamic-update-slice (and fusions rooted in one) counted as
#               2x the update slice (in-place semantics on TPU).
# ---------------------------------------------------------------------------

_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^=]*?\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(([^\n]*)$")
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
             "bitcast", "after-all", "while", "conditional", "call",
             "reshape", "partition-id", "replica-id", "iota",
             # donated state is aliased in place on TPU; scope-level copies
             # of inputs/outputs are CPU-runtime artifacts
             "copy", "copy-start", "copy-done"}
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")


def _result_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def _parse_ops(comp_text: str):
    """Yield (name, type_str, opname, args_str) per op line; also build a
    name -> result-bytes/shape table."""
    table = {}
    ops = []
    for line in comp_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, tstr, op, rest = m.groups()
        table[name] = tstr
        ops.append((name, tstr, op, rest))
    return ops, table


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims.strip() else []


_LAYOUT_OPS = {"convert", "bitcast", "copy", "transpose", "parameter",
               "tuple", "get-tuple-element", "reshape"}


def _is_layout_only(comp_text: str) -> bool:
    ops, _ = _parse_ops(comp_text)
    if not ops:
        return False
    return all(op in _LAYOUT_OPS for _, _, op, _ in ops)


def _comp_cost(comp_text: str, comps: Dict[str, str]):
    """(dot_flops, hbm_bytes, while_calls[(cond, body)]) for one computation
    body, loop bodies NOT yet expanded."""
    ops, table = _parse_ops(comp_text)
    flops = 0.0
    hbm = 0.0
    whiles = [(m.group(1), m.group(2)) for m in _WHILE_RE.finditer(comp_text)]
    for name, tstr, op, rest in ops:
        if op in _FREE_OPS:
            continue
        operands = _OPERAND_RE.findall(rest.split(" calls=")[0]
                                       .split(" to_apply=")[0])
        op_bytes = sum(_result_bytes(table[o]) for o in operands
                       if o in table)
        res_bytes = _result_bytes(tstr)
        if op == "dot":
            cm = _CONTRACT_RE.search(rest)
            k = 1
            if cm and operands and operands[0] in table:
                lhs_dims = _dims_of(table[operands[0]])
                for d in cm.group(1).split(","):
                    if d.strip() and int(d) < len(lhs_dims):
                        k *= lhs_dims[int(d)]
            out_elems = 1
            for d in _dims_of(tstr):
                out_elems *= d
            flops += 2.0 * out_elems * k
            hbm += op_bytes + res_bytes
            continue
        if op == "dynamic-update-slice":
            upd = (_result_bytes(table[operands[1]])
                   if len(operands) > 1 and operands[1] in table else res_bytes)
            hbm += 2 * upd
            continue
        if op in ("dynamic-slice", "slice", "gather"):
            # touches only the slice/rows, not the whole operand
            hbm += 2 * res_bytes
            continue
        if op == "fusion":
            cm = _CALLS_RE.search(rest)
            called = comps.get(cm.group(1), "") if cm else ""
            # dots inside fusions still execute on the MXU
            f_ops, f_table = _parse_ops(called)
            for fn_, ft_, fop_, frest_ in f_ops:
                if fop_ == "dot":
                    c2 = _CONTRACT_RE.search(frest_)
                    k = 1
                    f_operands = _OPERAND_RE.findall(frest_)
                    if c2 and f_operands and f_operands[0] in f_table:
                        ld = _dims_of(f_table[f_operands[0]])
                        for d in c2.group(1).split(","):
                            if d.strip() and int(d) < len(ld):
                                k *= ld[int(d)]
                    oe = 1
                    for d in _dims_of(ft_):
                        oe *= d
                    flops += 2.0 * oe * k
            if _is_layout_only(called):
                # pure dtype-convert / transpose / copy fusions are CPU-
                # backend materializations; the TPU path consumes bf16 with
                # kernel-internal layouts — excluded from the traffic model
                continue
            if "dynamic-update-slice" in called:
                # in-place buffer update (cache token write / scan-ys stack
                # insert): TPU aliases these; the true write is the updated
                # slice, already tiny vs the attention reads — counted as 0
                # here and noted as an undercount bound in the roofline doc.
                continue
            if "dynamic-slice(" in called or "gather(" in called:
                # slice-consuming fusion (per-layer weight/cache extraction
                # from the scanned stack): touches only the slice
                hbm += 2 * res_bytes
                continue
            hbm += op_bytes + res_bytes
            continue
        hbm += op_bytes + res_bytes
    return flops, hbm, whiles


# ---------------------------------------------------------------------------
# Donation / buffer-reuse introspection (used by repro.analysis.hlo_audit).
#
# A donated jit argument surfaces in the optimized HLO as an
# ``input_output_alias={ {out_idx}: (param, {path}, may-alias), ... }``
# module attribute; a donation FAILURE surfaces as the absence of that
# alias for a cache-sized output, or as a full-cache ``copy`` whose
# operand chains back to a parameter (copy-on-write of the input cache).
# ---------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(r"\{\s*([0-9,\s]*)\}\s*:\s*\(\s*(\d+)")
_ENTRY_LINE_RE = re.compile(r"^ENTRY\s+%?[\w.\-]+\s*\(.*?\)\s*->\s*(.+?)\s*\{?\s*$",
                            re.M)


def input_output_aliases(hlo_text: str) -> Dict[Tuple[int, ...], int]:
    """{output tuple index path: parameter number} from the module's
    ``input_output_alias`` attribute; empty when nothing is donated."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return {}
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, min(len(hlo_text), i + 100_000)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    else:
        return {}
    region = hlo_text[i + 1:j]
    out: Dict[Tuple[int, ...], int] = {}
    for m in _ALIAS_ENTRY_RE.finditer(region):
        path = tuple(int(p) for p in m.group(1).split(",") if p.strip())
        out[path] = int(m.group(2))
    return out


def entry_output_shapes(hlo_text: str):
    """[(dtype, dims, bytes)] per ENTRY result tuple element, in order."""
    m = _ENTRY_LINE_RE.search(hlo_text)
    if not m:
        return []
    return [(d, s, _shape_bytes(d, s))
            for d, s in _SHAPE_RE.findall(m.group(1))]


def find_copy_ops(hlo_text: str, min_bytes: int = 0):
    """Every ``copy`` op (async variants at -start) across all
    computations, with its operand resolved through gte/bitcast/reshape
    chains: [{name, bytes, computation, operand, operand_op,
    from_parameter}].  ``from_parameter`` marks copies whose source is an
    entry/loop parameter — the copy-on-write signature of a failed
    donation."""
    out = []
    for comp_name, text in _split_computations(hlo_text).items():
        ops, table = _parse_ops(text)
        kinds = {name: op for name, _, op, _ in ops}
        operands_of = {name: _OPERAND_RE.findall(rest.split(" calls=")[0]
                                                 .split(" to_apply=")[0])
                       for name, _, _, rest in ops}

        def chases_to_param(name: str, hops: int = 6) -> bool:
            while hops:
                kind = kinds.get(name)
                if kind == "parameter":
                    return True
                if kind not in ("get-tuple-element", "bitcast", "reshape",
                                "copy"):
                    return False
                opnds = operands_of.get(name) or []
                if not opnds:
                    return False
                name = opnds[0]
                hops -= 1
            return False

        for name, tstr, op, rest in ops:
            if op not in ("copy", "copy-start"):
                continue
            nbytes = _result_bytes(tstr)
            if nbytes < min_bytes:
                continue
            opnds = operands_of[name]
            src = opnds[0] if opnds else ""
            out.append({
                "name": name, "bytes": nbytes, "computation": comp_name,
                "operand": src, "operand_op": kinds.get(src, "?"),
                "from_parameter": chases_to_param(src)})
    return out


def full_analysis(hlo_text: str) -> Dict[str, float]:
    """Trip-multiplied {dot_flops, hbm_bytes} per device, plus the
    collective-bytes breakdown (collective_bytes())."""
    comps = _split_computations(hlo_text)
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    entry = m.group(1) if m else None
    memo: Dict[str, Tuple[float, float]] = {}
    fused = set()
    for name, text in comps.items():
        for cm in _CALLS_RE.finditer(text):
            fused.add(cm.group(1))

    def visit(name: str, depth: int = 0) -> Tuple[float, float]:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 20:
            return (0.0, 0.0)
        flops, hbm, whiles = _comp_cost(comps[name], comps)
        for cond, body in whiles:
            trips = _trip_count(comps.get(cond, ""))
            bf, bh = visit(body, depth + 1)
            flops += trips * bf
            hbm += trips * bh
        memo[name] = (flops, hbm)
        return memo[name]

    if entry is None:
        return {"dot_flops": 0.0, "hbm_bytes": 0.0}
    flops, hbm = visit(entry)
    out = {"dot_flops": float(flops), "hbm_bytes": float(hbm)}
    return out
