"""Jittable train / prefill / decode step builders shared by the launcher,
the dry-run, and the examples."""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.optim.adamw import AdamW, AdamWState


def make_train_step(model, opt: AdamW):
    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss
    return train_step


def make_prefill_step(model):
    def prefill_step(params, state, tokens):
        return model.prefill(params, state, tokens)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, state, tokens):
        return model.decode_step(params, state, tokens)
    return decode_step
