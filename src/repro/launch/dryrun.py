import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
must succeed on the (16,16) single-pod mesh and the (2,16,16) multi-pod
mesh; we record memory_analysis / cost_analysis / collective-byte parse
into results/dryrun/*.json for the roofline table (deliverable g).

The device-count override above MUST precede any other import — jax locks
the device count on first init.  Run:

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--fsdp auto|on|off] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_is_runnable, get_config
from repro.core.placement_bridge import (batch_shardings,
                                         decode_state_shardings,
                                         param_shardings)
from repro.launch.hlo_analysis import collective_bytes, full_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models.api import N_IMAGE_TOKENS, build_model, input_specs
from repro.models.partitioning import make_partitioner
from repro.optim.adamw import AdamW, AdamWState
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def serve_needs_fsdp(cfg) -> bool:
    """Params bf16 under pure TP16 must leave room for the KV cache."""
    return cfg.param_count() * 2 / 16 > 6e9


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp: str = "auto", remat: str = "full",
               capacity_moe: bool = False, extra_tags: dict | None = None,
               quant_serve: bool = False, kv_int8: bool = False,
               layout: str = "tp"):
    """Returns (jitted_fn, abstract_args) for one dry-run cell."""
    cfg = get_config(arch)
    if kv_int8:
        cfg = cfg.with_overrides(kv_quant=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    seq_over_data = shape.kind == "long-decode"
    if shape.kind == "train":
        use_fsdp = fsdp != "off"
    else:
        use_fsdp = serve_needs_fsdp(cfg) if fsdp == "auto" else fsdp == "on"
    use_sp = shape.kind == "train" and layout == "tp"
    part = make_partitioner(mesh, fsdp=use_fsdp, seq_over_data=seq_over_data,
                            sp=use_sp, layout=layout)
    # capacity-bucketed MoE dispatch for long-sequence cells (dense
    # dispatch is O(E/top_k) FLOP-inflated and memory-hungry); decode keeps
    # dense dispatch (1 token, negligible).
    use_cap = capacity_moe or (cfg.is_moe and shape.kind in ("train", "prefill"))
    model = build_model(cfg, tp=tp, part=part,
                        remat=remat if shape.kind == "train" else "none",
                        capacity_moe=use_cap)
    if quant_serve:
        # int8 weight-only serving: TP-resident int8 params, no FSDP gather
        use_fsdp = False
        part = make_partitioner(mesh, fsdp=False, seq_over_data=seq_over_data,
                                sp=use_sp)
        model = build_model(cfg, tp=tp, part=part,
                            remat="none", capacity_moe=use_cap)
    specs = input_specs(cfg, shape)
    if quant_serve:
        from repro.models.quantization import quantize_params
        params_shape = jax.eval_shape(
            lambda k: quantize_params(model.init(k)), jax.random.PRNGKey(0))
    else:
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = param_shardings(params_shape, cfg, mesh, fsdp=use_fsdp,
                           layout=layout)
    B, S = shape.global_batch, shape.seq_len
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "fsdp": use_fsdp, "remat": remat if shape.kind == "train" else "none",
            "seq_over_data": seq_over_data, "tp": tp, "sp": use_sp,
            "capacity_moe": use_cap, "quant_serve": quant_serve,
            "kv_int8": kv_int8, "layout": layout}
    if extra_tags:
        meta.update(extra_tags)

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_sh = AdamWState(step=NamedSharding(mesh, P()),
                          mu=param_shardings(opt_shape.mu, cfg, mesh,
                                             fsdp=use_fsdp, layout=layout),
                          nu=param_shardings(opt_shape.nu, cfg, mesh,
                                             fsdp=use_fsdp, layout=layout))
        b_sh = batch_shardings(specs, mesh, layout=layout)
        step = make_train_step(model, opt)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        batch = dict(specs)
        if cfg.family == "train-vlm":
            pass
        return fn, (params_shape, opt_shape, batch), mesh, meta

    # inference shapes ----------------------------------------------------
    extras = {}
    if cfg.family == "vlm":
        extras["img_embeds"] = jax.ShapeDtypeStruct(
            (B, N_IMAGE_TOKENS, cfg.d_model), jnp.dtype(cfg.dtype))
        extras["img_mask"] = jax.ShapeDtypeStruct((B, N_IMAGE_TOKENS),
                                                  jnp.bool_)
    state_shape = jax.eval_shape(
        lambda p, **kw: model.init_decode_state(p, B, S, **kw),
        params_shape, **extras)
    s_sh = decode_state_shardings(state_shape, cfg, mesh,
                                  seq_over_data=seq_over_data)
    if shape.kind == "prefill":
        step = make_prefill_step(model)
        tok = specs["tokens"]
        tok_sh = batch_shardings({"tokens": tok}, mesh)["tokens"]
        out_logits_sh = NamedSharding(
            mesh, P(("pod", "data") if multi_pod else ("data",), "model"))
        fn = jax.jit(step, in_shardings=(p_sh, s_sh, tok_sh),
                     out_shardings=(out_logits_sh, s_sh),
                     donate_argnums=(1,))
        return fn, (params_shape, state_shape, tok), mesh, meta
    # decode / long-decode
    step = make_decode_step(model)
    tok = specs["tokens"]
    if seq_over_data:
        tok_sh = NamedSharding(mesh, P())       # batch=1: replicated token
        out_logits_sh = NamedSharding(mesh, P(None, "model"))
    else:
        tok_sh = batch_shardings({"tokens": tok}, mesh)["tokens"]
        out_logits_sh = NamedSharding(
            mesh, P(("pod", "data") if multi_pod else ("data",), "model"))
    fn = jax.jit(step, in_shardings=(p_sh, s_sh, tok_sh),
                 out_shardings=(out_logits_sh, s_sh),
                 donate_argnums=(1,))
    return fn, (params_shape, state_shape, tok), mesh, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             fsdp: str = "auto", remat: str = "full",
             capacity_moe: bool = False, out_dir: Path = RESULTS_DIR,
             tag: str = "", extra_tags: dict | None = None,
             quant_serve: bool = False, kv_int8: bool = False,
             layout: str = "tp") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_dir.mkdir(parents=True, exist_ok=True)
    record: dict = {"cell": name}
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        (out_dir / f"{name}.json").write_text(json.dumps(record, indent=1))
        print(f"[dryrun] {name}: SKIPPED ({why})")
        return record
    t0 = time.time()
    try:
        fn, args, mesh, meta = build_cell(arch, shape_name, multi_pod,
                                          fsdp=fsdp, remat=remat,
                                          capacity_moe=capacity_moe,
                                          extra_tags=extra_tags,
                                          quant_serve=quant_serve,
                                          kv_int8=kv_int8, layout=layout)
        record.update(meta)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_d = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost_d = {k: float(v) for k, v in dict(cost).items()
                  if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        counts = coll.pop("_counts")
        # trip-aware FLOP/byte analysis (CPU cost_analysis counts while
        # bodies once — verified; see hlo_analysis.py)
        fa = full_analysis(hlo)
        record.update(
            status="ok", lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory_analysis=mem_d,
            flops=cost_d.get("flops", 0.0),
            bytes_accessed=cost_d.get("bytes accessed", 0.0),
            cost_analysis={k: v for k, v in cost_d.items()
                           if k in ("flops", "bytes accessed",
                                    "bytes accessed output",
                                    "optimal_seconds")},
            collective_bytes=coll, collective_counts=counts,
            dot_flops=fa["dot_flops"], hbm_bytes=fa["hbm_bytes"],
            hlo_bytes=len(hlo),
        )
        print(f"[dryrun] {name}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s flops={record['flops']:.3e} "
              f"coll_bytes={sum(coll.values()):.3e} "
              f"temp={mem_d.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"args={mem_d.get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {name}: ERROR {type(e).__name__}: {e}")
    (out_dir / f"{name}.json").write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on the single-pod mesh")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots", "dots_no_batch"])
    ap.add_argument("--capacity-moe", action="store_true")
    ap.add_argument("--quant-serve", action="store_true",
                    help="int8 weight-only params, TP-resident (no FSDP)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache with per-token-head scales")
    ap.add_argument("--layout", default="tp", choices=["tp", "zero3"],
                    help="zero3 = pure FSDP over the whole mesh (no TP)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape_name in SHAPES:
                run_cell(arch, shape_name, args.multi_pod, fsdp=args.fsdp,
                         remat=args.remat, capacity_moe=args.capacity_moe,
                         out_dir=out_dir, tag=args.tag)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    run_cell(args.arch, args.shape, args.multi_pod, fsdp=args.fsdp,
             remat=args.remat, capacity_moe=args.capacity_moe,
             out_dir=out_dir, tag=args.tag, quant_serve=args.quant_serve,
             kv_int8=args.kv_int8, layout=args.layout)


if __name__ == "__main__":
    main()
