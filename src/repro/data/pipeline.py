"""Synthetic token data pipeline with host-side prefetch and device
sharding — the training-substrate layer (no external datasets in this
environment; the pipeline's *interface* is the deliverable: sharded
device_put, double-buffered prefetch, deterministic per-step seeding,
checkpointable cursor).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token-prediction stream.

    Draws Zipf-distributed tokens (vocab-realistic) with a fixed per-step
    seed so a restarted job resumes bit-identically from the cursor —
    required for checkpoint/restart tests.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        self.step = 0

    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: Dict[str, Any]):
        self.step = int(d["step"])
        self.seed = int(d["seed"])

    def _sample(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) + step)
        raw = rng.zipf(self.zipf_a,
                       size=(self.global_batch, self.seq_len + 1))
        toks = (raw - 1) % self.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            batch = self._sample(self.step)
            self.step += 1        # advance BEFORE yielding: the cursor in
            yield batch           # state_dict() counts *consumed* batches


class ShardedPrefetcher:
    """Host->device double-buffering: a worker thread materializes numpy
    batches and device_puts them with the given shardings while the
    previous step computes."""

    def __init__(self, source: Iterator[Dict[str, np.ndarray]],
                 shardings: Optional[Dict[str, Any]] = None,
                 depth: int = 2):
        self.source = source
        self.shardings = shardings
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        for batch in self.source:
            if self._stop.is_set():
                return
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings[k])
                         for k, v in batch.items()}
            else:
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.5)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                return self.q.get(timeout=1.0)
            except queue.Empty:
                if not self.thread.is_alive():
                    raise StopIteration
                continue

    def close(self):
        self._stop.set()


def make_train_pipeline(cfg, shape, shardings=None, seed: int = 0,
                        prefetch: bool = True):
    src = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                      seed=seed)
    it = iter(src)
    if prefetch:
        return src, ShardedPrefetcher(it, shardings)
    return src, it
