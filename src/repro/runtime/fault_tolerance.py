"""Fault-tolerance runtime: heartbeat/step-time telemetry, straggler
detection, failure handling.

The central systems claim (DESIGN.md §9): the paper's resource-aware
algorithm doubles as the TPU straggler/memory-pressure policy.  Observed
per-slot step times are converted into the C_j(τ) availability estimates
Algorithm 1 consumes; slots flagged as stragglers get their head-shards
migrated away exactly like an overloaded edge device.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class SlotTelemetry:
    step_times: Deque[float]
    last_heartbeat: float
    alive: bool = True


class HeartbeatMonitor:
    """Tracks per-slot liveness + step-time EWMA; estimates effective
    compute availability for the controller.

    ``clock`` injects the time source (default wall clock): the async
    serving runtime's tests drive hang detection on a virtual clock, so
    "worker silent past the timeout" is provable without real sleeps."""

    def __init__(self, n_slots: int, *, window: int = 16,
                 straggler_factor: float = 1.5,
                 heartbeat_timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.slots: Dict[int, SlotTelemetry] = {
            j: SlotTelemetry(deque(maxlen=window), self._clock())
            for j in range(n_slots)}
        self.straggler_factor = straggler_factor
        self.heartbeat_timeout = heartbeat_timeout
        # event log: faults/recoveries with their cause, bounded like the
        # engine's sample_key_log (a long-running monitor must not grow)
        self.events: Deque[dict] = deque(maxlen=4096)

    def record_event(self, kind: str, **info):
        self.events.append({"kind": kind, "t": self._clock(), **info})

    def record_step(self, slot: int, seconds: float):
        t = self.slots[slot]
        t.step_times.append(seconds)
        t.last_heartbeat = self._clock()
        t.alive = True

    def record_heartbeat(self, slot: int):
        t = self.slots[slot]
        t.last_heartbeat = self._clock()
        t.alive = True      # a heartbeat revives a hang-flagged slot

    # ------------------------------------------------------------- queries
    def median_step(self) -> float:
        times = [np.mean(t.step_times) for t in self.slots.values()
                 if t.step_times]
        return float(np.median(times)) if times else 0.0

    def stragglers(self) -> List[int]:
        med = self.median_step()
        if med <= 0:
            return []
        return [j for j, t in self.slots.items()
                if t.step_times and np.mean(t.step_times)
                > self.straggler_factor * med]

    def dead(self) -> List[int]:
        now = self._clock()
        return [j for j, t in self.slots.items()
                if now - t.last_heartbeat > self.heartbeat_timeout]

    def sweep_hung(self, on_hung: Optional[Callable[[int], None]] = None
                   ) -> List[int]:
        """One-shot hang sweep (the async runtime's worker watchdog):
        slots silent past ``heartbeat_timeout`` transition to dead exactly
        once — the transition (not every poll) lands in the event log, and
        ``availability`` zeroes the slot until a heartbeat revives it.
        Returns the slots that newly transitioned this sweep.

        ``on_hung(slot)`` is the recovery escalation hook, invoked once
        per newly-hung slot AFTER the transition is logged (default None:
        the original log-only behavior).  Detection and recovery stay
        separable — the callback's own events land in the log too, so an
        escalation that raises is still attributable."""
        now = self._clock()
        newly: List[int] = []
        for j, t in self.slots.items():
            silent = now - t.last_heartbeat
            if silent > self.heartbeat_timeout and t.alive:
                t.alive = False
                newly.append(j)
                self.record_event("worker_hung", slot=j,
                                  silent_s=float(silent))
        if on_hung is not None:
            for j in newly:
                self.record_event("recovery_escalated", slot=j)
                on_hung(j)
        return newly

    def availability(self, peak_flops) -> np.ndarray:
        """C_j(τ) estimates for Algorithm 1: peak scaled by the inverse of
        the slot's slowdown relative to the median step time.  Dead slots
        estimate to 0.0.  ``peak_flops`` may be a scalar or a per-slot
        array (heterogeneous devices).  The estimate is monotone
        non-increasing in a slot's observed mean step time."""
        peak = np.broadcast_to(np.asarray(peak_flops, float),
                               (len(self.slots),)).astype(float).copy()
        med = self.median_step()
        out = peak.copy()
        for j, t in self.slots.items():
            if not t.alive:
                out[j] = 0.0
            elif med > 0 and t.step_times:
                out[j] = peak[j] * min(1.0,
                                       med / float(np.mean(t.step_times)))
        return out

    def mark_failed(self, slot: int):
        self.slots[slot].alive = False


class RestartPolicy:
    """Checkpoint-restart orchestration: on failure, roll back to the last
    committed step and re-enter the train loop; bounded retries with
    exponential backoff (production default 3 retries)."""

    def __init__(self, checkpointer, *, max_retries: int = 3,
                 backoff_s: float = 5.0,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.ckpt = checkpointer
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.failures = 0
        self.monitor = monitor
        self.events: Deque[dict] = deque(maxlen=4096)

    def _record_fault(self, e: BaseException, resume_step):
        """What failed, not just that something failed: the exception
        type/message lands in the policy's (and the monitor's) event log
        so a swallowed retry is still attributable post-mortem."""
        ev = {"kind": "worker_fault", "error_type": type(e).__name__,
              "error": str(e), "failures": self.failures,
              "resume_step": resume_step, "t": time.monotonic()}
        self.events.append(ev)
        if self.monitor is not None:
            self.monitor.record_event(**ev)

    def run(self, train_fn: Callable[[Optional[int]], None]):
        """train_fn(resume_step) runs until completion or raises."""
        while True:
            resume = self.ckpt.latest_step()
            try:
                train_fn(resume)
                return
            except Exception as e:  # noqa: BLE001 — any worker fault
                self.failures += 1
                self._record_fault(e, resume)
                if self.failures > self.max_retries:
                    raise
                time.sleep(self.backoff_s * 2 ** (self.failures - 1))
