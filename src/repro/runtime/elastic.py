"""Elastic scaling: rebuild the mesh on a changed device set and re-shard
state from the last checkpoint.

A checkpoint written on one mesh restores onto any other (the checkpointer
stores unsharded host arrays and device_puts with the *new* shardings), so
shrink/grow is: detect -> choose new mesh shape -> rebuild shardings ->
restore.  The controller then re-runs Algorithm 1 on the new slot set —
the paper's migration machinery provides the placement on the resized
cluster for free.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.checkpoint.checkpointer import Checkpointer


def best_mesh_shape(n_devices: int, *, prefer_model: int = 16
                    ) -> Tuple[int, int]:
    """(data, model) for an arbitrary surviving device count: the largest
    power-of-two model degree <= prefer_model that divides n_devices
    (head-level TP needs uniform shards and our head counts divide powers
    of two), rest to data."""
    model = 1
    while (model * 2 <= min(prefer_model, n_devices)
           and n_devices % (model * 2) == 0):
        model *= 2
    return n_devices // model, model


class ElasticMesh:
    def __init__(self, devices=None, prefer_model: int = 16):
        self.devices = list(devices if devices is not None else jax.devices())
        self.prefer_model = prefer_model
        self.mesh = self._build()

    def _build(self):
        n = len(self.devices)
        data, model = best_mesh_shape(n, prefer_model=self.prefer_model)
        import numpy as np
        dev_array = np.array(self.devices[:data * model]).reshape(data, model)
        from jax.sharding import Mesh
        return Mesh(dev_array, ("data", "model"))

    def resize(self, devices) -> "ElasticMesh":
        return ElasticMesh(devices, self.prefer_model)


def elastic_restore(ckpt: Checkpointer, step: int, like_tree,
                    make_shardings, mesh):
    """Restore a checkpoint onto a (possibly different) mesh.
    ``make_shardings(mesh)`` builds the sharding pytree for that mesh."""
    return ckpt.restore(step, like_tree, shardings=make_shardings(mesh))
