"""Async serving front end over the synchronous ``ServingEngine``.

Everything below the queue is the existing engine, untouched: one JAX
host thread, slot-level continuous batching, the controller's migration
loop.  This module adds the *serving* shape production traffic needs —
admission, streaming, backpressure, drain — as cooperatively-scheduled
asyncio workers (the maxtext JetThread/queue-overlap pattern, expressed
as coroutines because the engine is single-host and not thread-safe):

``admission worker``   pops the bounded inbox and feeds the engine's
    FIFO (``engine.submit``) — prompts land in the engine queue while
    the decode worker is mid-step, so chunked prefill of the next
    request overlaps the current batch's decode at the scheduler level.
``decode worker``      drives ``engine.step()``: with ``pipeline_k=K``
    each step advances one in-flight group, so K tokens stay in flight
    across layer-disjoint stages exactly as in the synchronous engine.
``watchdog``           sweeps a ``HeartbeatMonitor`` over the workers;
    a hung worker (no heartbeat past the timeout) is detected, logged
    once into the monitor's event log, and zeroed in ``availability``
    — the first consumer of the formerly-orphaned fault-tolerance
    runtime on the serving path.

Per-request streaming rides the engine's ``token_sink`` hook: every
generated token is routed to its request's ``AsyncRequestHandle``, an
async generator the caller iterates while other requests decode.

Backpressure is a TYPED reject at submit time (``QueueFullError``) when
the bounded inbox is full — load shedding happens at admission, never
mid-stream.

Determinism: the inbox is FIFO and admission is atomic (no await between
dequeue and ``engine.submit``), so the engine sees the same request
order as a synchronous caller issuing the same ``submit`` sequence — and
because greedy decode is per-slot independent, the async per-request
token streams are BIT-IDENTICAL to the synchronous engine's
(tests/test_async_serving.py asserts this on dense and paged engines).

    eng = ServingEngine(cfg, n_slots=4, paged=True)
    async with AsyncServingEngine(eng, queue_limit=64) as rt:
        h = rt.submit(prompt, max_new_tokens=32)
        async for tok in h.stream():
            ...
        await rt.drain()        # graceful: every accepted request done
"""
from __future__ import annotations

import asyncio
import collections
import time
from typing import AsyncIterator, Deque, Dict, List, Optional

import numpy as np

from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serving.engine import Request, ServingEngine


class QueueFullError(RuntimeError):
    """Typed backpressure signal: the bounded admission queue is full.
    The caller sheds or retries; nothing was enqueued."""


_END = object()          # stream sentinel: the engine retired the request


class AsyncRequestHandle:
    """One submitted request: an async token stream plus completion
    bookkeeping.  ``tokens`` accumulates the full output (so ``result``
    and ``stream`` compose); wall-clock ``t_submit/t_first/t_done`` give
    the async bench its TTFT samples."""

    def __init__(self, prompt: np.ndarray, max_new_tokens: int):
        self.prompt = np.asarray(prompt, np.int32)
        self.max_new_tokens = int(max_new_tokens)
        self.rid: Optional[int] = None        # engine id, set at admission
        self.tokens: List[int] = []
        self.error: Optional[BaseException] = None
        self.t_submit = time.monotonic()
        self.t_first: Optional[float] = None
        self.t_done: Optional[float] = None
        self._q: asyncio.Queue = asyncio.Queue()
        self._finished = asyncio.Event()

    async def stream(self) -> AsyncIterator[int]:
        """Yield this request's tokens as the engine generates them; the
        generator ends when the engine retires the request.  Raises the
        admission error, if any, instead of silently ending empty."""
        while True:
            item = await self._q.get()
            if item is _END:
                if self.error is not None:
                    raise self.error
                return
            yield item

    async def result(self) -> List[int]:
        """Await completion and return the full output stream."""
        await self._finished.wait()
        if self.error is not None:
            raise self.error
        return self.tokens


class AsyncServingEngine:
    """Bounded-queue async runtime over one ``ServingEngine`` (wave
    engines have no incremental scheduler to drive).  Single event loop,
    three tasks; see the module docstring for the worker split."""

    ADMISSION, DECODE = 0, 1          # worker ids in the heartbeat monitor

    def __init__(self, engine: ServingEngine, *, queue_limit: int = 64,
                 heartbeat_timeout: float = 30.0,
                 idle_poll_s: float = 0.02,
                 heartbeat_clock=None,
                 escalate_hangs: bool = True):
        if not isinstance(engine, ServingEngine):
            raise TypeError("AsyncServingEngine drives the slot-level "
                            "ServingEngine (continuous batching); got "
                            f"{type(engine).__name__}")
        if engine.token_sink is not None:
            raise ValueError("engine already has a token_sink installed")
        self.engine = engine
        engine.token_sink = self._route
        self.queue_limit = int(queue_limit)
        self._inbox: Deque[AsyncRequestHandle] = collections.deque()
        self._handles: Dict[int, AsyncRequestHandle] = {}
        kw = {} if heartbeat_clock is None else {"clock": heartbeat_clock}
        # satellite of ROADMAP's fault-tolerance item: the serving path
        # finally OWNS a heartbeat monitor — over its workers, so a hung
        # decode loop is detected/logged even though full elastic churn
        # (device-level evacuation) is a later PR
        self.monitor = HeartbeatMonitor(
            2, heartbeat_timeout=heartbeat_timeout, **kw)
        self.idle_poll_s = float(idle_poll_s)
        self.escalate_hangs = bool(escalate_hangs)
        self._wake: Optional[asyncio.Event] = None
        self._tasks: List[asyncio.Task] = []
        self._watch: Optional[asyncio.Task] = None
        self._draining = False
        self._started = False

    # ----------------------------------------------------------- intake
    @property
    def queue_depth(self) -> int:
        """Requests accepted but not yet resident in a slot."""
        return len(self._inbox) + len(self.engine.queue)

    def submit(self, prompt: np.ndarray,
               max_new_tokens: int = 32) -> AsyncRequestHandle:
        """Enqueue one request; returns its stream handle immediately.
        Raises ``QueueFullError`` (typed, nothing enqueued) when the
        bounded inbox is at ``queue_limit`` — backpressure belongs at
        admission, not mid-stream."""
        if self._draining:
            raise RuntimeError("runtime is draining: submissions closed")
        if len(self._inbox) >= self.queue_limit:
            raise QueueFullError(
                f"admission queue full ({self.queue_limit} pending); "
                f"shed or retry after the backlog drains")
        h = AsyncRequestHandle(prompt, max_new_tokens)
        self._inbox.append(h)
        if self._wake is not None:
            self._wake.set()
        return h

    # ------------------------------------------------------------ routing
    def _route(self, req: Request, tok: Optional[int], done: bool):
        """Engine token_sink: fan tokens out to per-request streams.
        Runs synchronously inside ``engine.step`` on the event-loop
        thread, so put_nowait ordering matches generation order."""
        h = self._handles.get(req.rid)
        if h is None:
            return            # request submitted around the runtime
        if done:
            h.t_done = time.monotonic()
            self._handles.pop(req.rid)
            h._finished.set()
            h._q.put_nowait(_END)
            return
        if h.t_first is None:
            h.t_first = time.monotonic()
        h.tokens.append(tok)
        h._q.put_nowait(tok)

    def _fail_handle(self, h: AsyncRequestHandle, e: BaseException):
        h.error = e
        h.t_done = time.monotonic()
        h._finished.set()
        h._q.put_nowait(_END)

    # ------------------------------------------------------------ workers
    async def _idle_wait(self):
        self._wake.clear()
        try:
            await asyncio.wait_for(self._wake.wait(),
                                   timeout=self.idle_poll_s)
        except asyncio.TimeoutError:
            pass              # periodic poll: re-check drain conditions

    async def _admission_worker(self):
        while True:
            self.monitor.record_heartbeat(self.ADMISSION)
            if self._inbox:
                h = self._inbox.popleft()
                # atomic dequeue->submit->register (no await in between):
                # the handle is routable before the decode worker can
                # emit its first token, and FIFO order is preserved — the
                # bit-identity-with-sync contract hangs on this
                try:
                    h.rid = self.engine.submit(h.prompt, h.max_new_tokens)
                    self._handles[h.rid] = h
                except ValueError as e:
                    # intake-time reject (e.g. prompt exceeds max bucket):
                    # surfaced on THIS handle's stream, not the runtime
                    self._fail_handle(h, e)
                self._wake.set()          # decode worker may be idling
                await asyncio.sleep(0)    # overlap: let decode interleave
                continue
            if self._draining:
                return
            await self._idle_wait()

    async def _decode_worker(self):
        while True:
            self.monitor.record_heartbeat(self.DECODE)
            t0 = time.monotonic()
            if self.engine.step():
                self.monitor.record_step(self.DECODE,
                                         time.monotonic() - t0)
                await asyncio.sleep(0)    # stream consumers + admission
                continue
            # idle: nothing resident.  A non-empty engine queue here can
            # never admit (all slots/pages are free and it still did not
            # fit) — fail loudly instead of spinning forever.
            if self.engine.queue:
                raise RuntimeError(
                    "idle engine cannot admit its head-of-line request "
                    f"(queue={len(self.engine.queue)}): request footprint "
                    "exceeds the engine's page pool / slot capacity")
            if self._draining and not self._inbox:
                return
            await self._idle_wait()

    async def _watchdog(self):
        period = max(self.monitor.heartbeat_timeout / 2.0,
                     self.idle_poll_s)
        while True:
            await asyncio.sleep(period)
            self.check_workers()

    def check_workers(self) -> List[int]:
        """Sweep the worker heartbeat monitor: newly-hung workers (silent
        past the timeout) are logged once into ``monitor.events`` and
        returned.  The watchdog calls this periodically; tests call it
        directly on a virtual clock.

        With ``escalate_hangs`` (the default) a newly-hung worker
        additionally ESCALATES to controller recovery instead of only
        being logged: the engine controller re-reads C_j(τ) from the
        device monitor (hung/failed devices estimate to zero) and the
        next scheduler step is forced to re-run Algorithm 1 — so a stall
        triggers re-placement in one watchdog period rather than waiting
        out the λ cadence."""
        on_hung = self._escalate if self.escalate_hangs else None
        return self.monitor.sweep_hung(on_hung=on_hung)

    def _escalate(self, worker: int):
        """worker_hung → controller recovery (ROADMAP's log-only watchdog
        gap): refresh the controller's availability view from the engine's
        device monitor and force a replan at the next step."""
        eng = self.engine
        eng.controller.observe_monitor(eng.monitor,
                                       peak_flops=eng.net.compute_avail)
        eng.request_replan()

    # ---------------------------------------------------------- lifecycle
    def start(self):
        """Spawn the admission/decode workers + watchdog on the running
        event loop (``async with`` does this for you)."""
        if self._started:
            raise RuntimeError("runtime already started")
        self._started = True
        self._wake = asyncio.Event()
        self._tasks = [
            asyncio.create_task(self._admission_worker(), name="admission"),
            asyncio.create_task(self._decode_worker(), name="decode"),
        ]
        self._watch = asyncio.create_task(self._watchdog(), name="watchdog")

    async def drain(self):
        """Graceful shutdown: close intake, run every accepted request to
        completion, stop the workers.  Afterwards the engine is empty —
        no resident slots, and a paged engine holds zero live pages
        (asserted via ``check_invariants`` in the tests)."""
        if not self._started:
            raise RuntimeError("start() the runtime before draining")
        self._draining = True
        self._wake.set()
        try:
            await asyncio.gather(*self._tasks)
        finally:
            for t in self._tasks:
                t.cancel()
            await asyncio.gather(*self._tasks, return_exceptions=True)
            self._tasks = []
            await self._stop_watchdog()

    async def _stop_watchdog(self):
        if self._watch is not None:
            self._watch.cancel()
            try:
                await self._watch
            except asyncio.CancelledError:
                pass          # cooperative cancel is the expected exit
            self._watch = None

    async def aclose(self):
        """Idempotent close: drain if workers are still up."""
        if self._tasks:
            await self.drain()
        else:
            await self._stop_watchdog()

    async def __aenter__(self) -> "AsyncServingEngine":
        self.start()
        return self

    async def __aexit__(self, exc_type, exc, tb):
        if exc_type is None:
            await self.aclose()
        else:
            # error path: abandon in-flight work instead of draining
            for t in self._tasks:
                t.cancel()
            await asyncio.gather(*self._tasks, return_exceptions=True)
            self._tasks = []
            await self._stop_watchdog()
