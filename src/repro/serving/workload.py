"""Deterministic seeded workload driver: arrival processes + mixed
length distributions + a virtual-clock load loop.

Everything the tail-latency benchmarks measure is generated here, from
ONE ``np.random.default_rng(seed)`` stream per workload — the same seed
always yields the same arrival times, prompts, and token budgets, so the
percentile metrics ``drive_virtual`` reports are bit-reproducible and CI
can gate them at the strict tolerance (a wall-clock load test could
only ever be gated loosely).

Arrival processes (all return sorted arrival times on ``[0, horizon)``):

``poisson``   homogeneous Poisson — exponential inter-arrival gaps at
              ``rate`` requests per time unit (the M/·/· baseline).
``bursty``    2-state MMPP (Markov-modulated Poisson): dwell times are
              exponential with mean ``mean_dwell`` and the instantaneous
              rate flips between ``rate`` and ``rate_hi`` — the classic
              burst model; same mean-ish load as Poisson but a heavier
              inter-arrival tail.
``diurnal``   nonhomogeneous Poisson via thinning against ``rate_hi``:
              the rate ramps ``rate → rate_hi → rate`` sinusoidally with
              ``period`` — the daily-traffic shape, so a run crosses
              under- and over-provisioned regimes in one sweep.

Clocks: ``VirtualClock`` is the test/bench time source — one scheduler
step costs ``step_dt`` and idle gaps jump to the next arrival, so a load
sweep is deterministic and takes no wall time beyond the model math.
``WallClock`` is the same interface read from ``time.monotonic`` for the
async runtime's real-traffic path (it cannot be advanced).

``drive_virtual(engine, requests)`` is the load loop itself: submit
arrivals as virtual time passes, step the engine, and timestamp every
generated token through the engine's ``token_sink`` stream hook.  It
reports p50/p95/p99 TTFT (arrival -> first token, queue wait included)
and inter-token latency, plus goodput (finished tokens per time unit) —
the serving metrics ROADMAP names as what every later PR should move.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# (weight, lo, hi) mixture components: mostly short chat-style prompts
# with a heavy tail of long ones — the mixed-length regime continuous
# batching exists for (benchmarks/serving_throughput.py's motivation).
DEFAULT_PROMPT_MIX: Tuple[Tuple[float, int, int], ...] = (
    (0.75, 4, 12), (0.25, 16, 32))
DEFAULT_OUT_MIX: Tuple[Tuple[float, int, int], ...] = (
    (0.7, 4, 10), (0.3, 12, 24))


@dataclasses.dataclass(frozen=True)
class TimedRequest:
    """One workload arrival: submit ``prompt`` at ``t_arrival``."""
    t_arrival: float
    prompt: np.ndarray            # (L0,) int32
    max_new_tokens: int


class VirtualClock:
    """Deterministic simulated time: ``advance`` moves it, nothing else
    does.  ``now`` is also usable as a ``HeartbeatMonitor`` clock."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float):
        if dt < 0:
            raise ValueError(f"virtual time cannot go backwards (dt={dt})")
        self._t += dt

    def advance_to(self, t: float):
        self._t = max(self._t, float(t))


class WallClock:
    """The real-time source with the VirtualClock interface; ``advance``
    is a no-op because wall time advances itself."""

    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float):
        pass

    def advance_to(self, t: float):
        pass


# --------------------------------------------------------------- processes
def poisson_arrivals(rate: float, horizon: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson: exponential gaps at ``rate``."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= horizon:
            return np.asarray(out, float)
        out.append(t)


def mmpp_arrivals(rate: float, rate_hi: float, mean_dwell: float,
                  horizon: float, rng: np.random.Generator) -> np.ndarray:
    """2-state MMPP: exponential dwells alternate the instantaneous rate
    between ``rate`` (quiet) and ``rate_hi`` (burst)."""
    if min(rate, rate_hi, mean_dwell) <= 0:
        raise ValueError("rate, rate_hi, mean_dwell must be positive")
    out: List[float] = []
    t, burst = 0.0, False
    while t < horizon:
        end = min(t + rng.exponential(mean_dwell), horizon)
        r = rate_hi if burst else rate
        tt = t
        while True:
            tt += rng.exponential(1.0 / r)
            if tt >= end:
                break
            out.append(tt)
        t, burst = end, not burst
    return np.asarray(out, float)


def diurnal_arrivals(rate: float, rate_hi: float, period: float,
                     horizon: float, rng: np.random.Generator) -> np.ndarray:
    """Nonhomogeneous Poisson by thinning: sinusoidal ramp
    ``rate -> rate_hi -> rate`` over each ``period`` (trough at t=0)."""
    if not rate_hi >= rate > 0:
        raise ValueError(f"need rate_hi >= rate > 0, got {rate}, {rate_hi}")
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_hi)
        if t >= horizon:
            return np.asarray(out, float)
        lam = rate + (rate_hi - rate) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * t / period))
        if rng.uniform() * rate_hi < lam:
            out.append(t)


def _sample_mix(rng: np.random.Generator,
                mix: Sequence[Tuple[float, int, int]]) -> int:
    w = np.asarray([m[0] for m in mix], float)
    i = int(rng.choice(len(mix), p=w / w.sum()))
    _, lo, hi = mix[i]
    return int(rng.integers(lo, hi + 1))


def make_workload(process: str = "poisson", *, rate: float,
                  horizon: float, seed: int = 0, vocab: int = 97,
                  prompt_mix: Sequence[Tuple[float, int, int]]
                  = DEFAULT_PROMPT_MIX,
                  out_mix: Sequence[Tuple[float, int, int]]
                  = DEFAULT_OUT_MIX,
                  rate_hi: Optional[float] = None,
                  mean_dwell: Optional[float] = None,
                  period: Optional[float] = None) -> List[TimedRequest]:
    """Seeded workload: arrivals from ``process``, prompt/output lengths
    from (weight, lo, hi) mixtures, tokens uniform over ``vocab``.  One
    rng drives everything, so equal seeds give equal workloads."""
    rng = np.random.default_rng(seed)
    if process == "poisson":
        times = poisson_arrivals(rate, horizon, rng)
    elif process == "bursty":
        times = mmpp_arrivals(rate, rate_hi or 4.0 * rate,
                              mean_dwell or horizon / 8.0, horizon, rng)
    elif process == "diurnal":
        times = diurnal_arrivals(rate, rate_hi or 3.0 * rate,
                                 period or horizon / 2.0, horizon, rng)
    else:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(poisson | bursty | diurnal)")
    out = []
    for t in times:
        L0 = _sample_mix(rng, prompt_mix)
        prompt = rng.integers(0, vocab, size=L0).astype(np.int32)
        out.append(TimedRequest(float(t), prompt,
                                _sample_mix(rng, out_mix)))
    return out


def offered_load(reqs: Sequence[TimedRequest], horizon: float) -> dict:
    """What the workload asks of the engine, per time unit."""
    toks = sum(len(r.prompt) + r.max_new_tokens for r in reqs)
    return {"req_rate": len(reqs) / horizon, "tok_rate": toks / horizon}


# ------------------------------------------------------------------ driver
def _pctls(xs: Sequence[float], prefix: str) -> Dict[str, float]:
    if not len(xs):
        return {f"p{p}_{prefix}": 0.0 for p in (50, 95, 99)}
    return {f"p{p}_{prefix}": float(np.percentile(xs, p))
            for p in (50, 95, 99)}


def drive_virtual(eng, reqs: Sequence[TimedRequest], *,
                  step_dt: float = 1.0,
                  max_steps: int = 200_000,
                  price_by_model: bool = False,
                  events: Optional[Sequence[tuple]] = None) -> dict:
    """Run ``reqs`` through a (synchronous) serving engine on a virtual
    clock: each scheduler step costs ``step_dt`` (pipeline bubbles
    included — an empty due group still burns time), idle gaps jump to
    the next arrival.  Tokens are timestamped via the engine's
    ``token_sink`` hook, so TTFT includes the queueing delay between a
    request's *arrival* and its first emitted token — the tail the
    offered-load sweep exists to expose.

    ``price_by_model`` prices each step by the controller's own modeled
    per-token pipeline delay instead of the flat ``step_dt``: the most
    recent interval's ``d_pipe_est`` (falling back to ``step_dt`` until
    the first interval fires, or while the estimate is non-finite).  The
    reported percentiles then reflect the placement the controller chose
    — a device slowdown or evacuation shows up in the latency tail
    instead of being flattened by the uniform step price.  Off by
    default: the flat pricing (and its committed baselines) stays
    bit-identical.

    ``events`` is a sequence of ``(t, fn)`` pairs: at the first loop
    iteration where virtual time has reached ``t``, ``fn(eng)`` runs —
    the churn injection hook (kill/slow/rejoin a device mid-decode).
    Events fire in time order, before arrivals are submitted.

    Deterministic: same engine seed + same workload (and same events) =>
    identical streams AND identical latency percentiles,
    machine-independent."""
    clock = VirtualClock()
    pending = collections.deque(sorted(reqs, key=lambda r: r.t_arrival))
    due = collections.deque(
        sorted(events or (), key=lambda e: e[0]))
    arrival: Dict[int, float] = {}
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    itl: List[float] = []
    prev_sink = eng.token_sink

    def sink(req, tok, done):
        if done:
            return
        now = clock.now()
        if req.rid in first:
            itl.append(now - last[req.rid])
        else:
            first[req.rid] = now
        last[req.rid] = now

    def _step_price() -> float:
        if not price_by_model:
            return step_dt
        log = getattr(eng, "migration_log", None)
        if log:
            d = log[-1].get("d_pipe_est")
            if d is not None and np.isfinite(d) and d > 0:
                return float(d)
        return step_dt

    eng.token_sink = sink
    try:
        while True:
            while due and due[0][0] <= clock.now():
                due.popleft()[1](eng)
            while pending and pending[0].t_arrival <= clock.now():
                tr = pending.popleft()
                rid = eng.submit(tr.prompt,
                                 max_new_tokens=tr.max_new_tokens)
                arrival[rid] = tr.t_arrival
            if eng.step():
                clock.advance(_step_price())
            elif pending or due:
                # idle: jump to whichever comes first, the next arrival
                # or the next churn event (events must fire even in gaps)
                nxt = []
                if pending:
                    nxt.append(pending[0].t_arrival)
                if due:
                    nxt.append(due[0][0])
                clock.advance_to(min(nxt))
            elif eng.queue:
                raise RuntimeError(
                    "engine idle with a queued head-of-line request it "
                    "can never admit (pool smaller than one request?)")
            else:
                break
            if eng.decode_steps >= max_steps:
                break
    finally:
        eng.token_sink = prev_sink
    ttft = [first[rid] - arrival[rid] for rid in sorted(first)]
    elapsed = max(clock.now(), step_dt)
    done_toks = sum(len(r.out_tokens) for r in eng.finished)
    out = {"n_submitted": len(arrival), "n_finished": len(eng.finished),
           "steps": eng.decode_steps, "t_end": clock.now(),
           "goodput": done_toks / elapsed,
           "streams": {r.rid: list(r.out_tokens) for r in eng.finished},
           "ttft": ttft, "itl": itl}
    out.update(_pctls(ttft, "ttft"))
    out.update(_pctls(itl, "itl"))
    return out
