"""Serving engines with the paper's controller in the loop.

Two schedulers over the same model/controller stack:

``ServingEngine`` (continuous batching, the production path)
  A persistent ``(n_slots, max_seq)`` KV cache with per-slot positions.
  Any queued request is admitted into any free slot the moment one frees:
  the prompt is right-padded to a small set of bucketed lengths (so prefill
  JIT recompiles stay bounded), prefilled at batch 1, and spliced into the
  slot's cache row (``insert_slot``).  Decode runs one step for the whole
  batch with per-slot attention masking, so slots at different sequence
  depths generate together — no equal-prompt-length restriction and no
  wave barrier.  This is the slot-based decode path production systems use
  (MaxText-style prefill-then-insert; Pope et al. 2022).

``WaveServingEngine`` (the old static scheduler, kept as the baseline)
  Up to ``n_slots`` equal-length prompts form a wave; the wave prefills as
  one batch and decodes in lock-step until every request finishes.  Freed
  slots stay dead until the wave drains and each new prompt length costs a
  fresh prefill compile — ``benchmarks/serving_throughput.py`` quantifies
  the gap.

``ServingEngine(pipeline_k=K)`` adds cross-device decode pipelining: slots
split into K groups with independent decode states and each step advances
one group, so K different requests' tokens are in flight across
layer-disjoint placement stages (delay.pipelined_inference_delay prices
the overlap; benchmarks/pipelined_decode.py measures it).  GQA archs now
migrate *physically* at KV-group granularity (group-consistent
permutations from placement_bridge), and VLM decode states are slot-wired
(per-request image K/V spliced by insert_slot) — both former skip paths.

Every λ generated tokens (λ·pipeline_k scheduler steps) the
IntervalController observes step-time telemetry
plus the *actual* per-slot cache occupancy, re-runs Algorithm 1, and
applies head migrations to weights AND cache in the inter-step gap — the
paper's per-interval migration loop as a production serving feature.
Under continuous batching the migrated cache holds slots at unequal
positions, the realistic version of §III.D's loop.

On a single CPU host this runs unsharded (NULL partitioner) and the
controller drives a *simulated* slot network — the same code path the TPU
deployment uses with mesh slots.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.blocks import CostModel
from repro.core.controller import ControllerConfig, IntervalController
from repro.core.network import DeviceNetwork
from repro.models.api import build_model
from repro.runtime.fault_tolerance import HeartbeatMonitor


class UnsupportedArchError(NotImplementedError):
    """Raised at ENGINE CONSTRUCTION for architectures the slot-level
    scheduler cannot serve — never mid-serve: by the time requests flow,
    the config has already been vetted.  Subclasses NotImplementedError so
    pre-existing callers' except clauses keep working."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L0,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    img: Optional[np.ndarray] = None       # (I, D) VLM patch embeddings
    img_mask: Optional[np.ndarray] = None  # (I,) bool


def supports_continuous(cfg: ModelConfig,
                        max_seq: Optional[int] = None) -> Optional[str]:
    """None when ``cfg`` can run the slot-level scheduler, else the reason
    it can't (cfg-only, so ``make_engine`` decides before building params).
    VLM states are slot-wired (img_kv/img_mask splice in
    ``TransformerLM.insert_slot``), and int8 KV caches (``kv_quant``) are
    continuous too — ``insert_slot`` splices the quantized values AND
    their per-(token, head) scales, and decode scatters per-slot writes
    into the int8 buffers — so neither falls back any more.

    Sliding-window archs (Mixtral) allocate a ring cache only when the
    served extent reaches the window (``init_cache``: T = min(max_seq,
    window)); serving with ``max_seq`` STRICTLY below the window keeps the
    cache linear, so the slot scheduler — and with it continuous MoE
    serving with applied expert migrations — applies.  Callers that don't
    know the extent yet (``max_seq=None``) get the conservative reject."""
    if cfg.family in ("ssm", "hybrid"):
        return f"{cfg.family} archs have no prefill_bucketed/insert_slot API"
    if cfg.sliding_window and (max_seq is None
                               or max_seq >= cfg.sliding_window):
        return ("continuous batching needs a linear KV cache, not a ring; "
                f"serve with max_seq < sliding_window "
                f"({cfg.sliding_window}) to keep the cache linear")
    return None


def default_buckets(max_seq: int, lo: int = 8) -> List[int]:
    """Power-of-two prompt buckets up to ``max_seq``: the prefill compile
    count is bounded by len(buckets), not by the number of distinct prompt
    lengths."""
    out, b = [], lo
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return sorted(set(out))


class _EngineBase:
    """Model + controller wiring and the PRNG-disciplined sampler shared by
    both schedulers."""

    def __init__(self, cfg: ModelConfig, *, n_slots: int = 4,
                 max_seq: int = 512, lam: int = 16, seed: int = 0,
                 net: Optional[DeviceNetwork] = None, cost_cfg=None,
                 part=None, tp: int = 1, greedy: bool = True,
                 layer_mode: str = "graph", pipeline_k: int = 1,
                 use_kernel: bool = False, search: str = "rescoring",
                 cost_page_size: int = 0):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        self.pipeline_k = max(1, int(pipeline_k))
        # search="bottleneck" (with pipeline_k > 1): controller plans come
        # from the bottleneck-targeted placement search, so the real cache/
        # weight migrations below follow the steady-state objective.
        self.search = search
        # use_kernel: decode attention runs the Pallas flash-decode kernel
        # (auto-interpreted on CPU) with its grid derived from the
        # controller's placement — see _refresh_head_rows.
        self.use_kernel = use_kernel
        from repro.models.partitioning import NULL
        self.model = build_model(cfg, tp=tp, part=part or NULL,
                                 use_kernel=use_kernel)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        if cfg.is_moe and isinstance(self.params.get("layers"), dict) \
                and "moe" in self.params["layers"]:
            # identity physical-expert maps: expert migrations permute the
            # weight rows AND these maps; the combine scatters rows back to
            # logical order (models.moe), so installing identity here is a
            # bit-exact no-op until the first expert migration
            from repro.models.moe import expert_identity
            own, sh = expert_identity(cfg.n_experts, cfg.n_layers)
            self.params["layers"]["moe"] = dict(
                self.params["layers"]["moe"], owner=own, share=sh)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._rid = 0
        # per-token stream hook: ``token_sink(req, tok, done)`` fires on
        # every generated token (done=False) and once at retire
        # (tok=None, done=True) — the async runtime routes these into
        # per-request streams and the workload driver timestamps them.
        # Purely observational: None (the default) changes nothing.
        self.token_sink: Optional[Callable[[Request, Optional[int], bool],
                                           None]] = None
        # load-signal marks: arrivals/steps since the last interval, so
        # the controller sees the observed arrival rate (requests per
        # scheduler step) and queue depth, not just slot occupancy.
        self._load_mark_step = 0
        self._load_mark_rid = 0
        # controller wiring (the paper's technique in the serving loop).
        # The controller's cost model can use the FULL production dims
        # (cost_cfg) while a reduced model serves on CPU — the placement
        # problem is the production one either way.
        n_dev = net.n_devices if net is not None else max(tp, 4)
        self.net = net or DeviceNetwork.sample(n_dev, seed=seed + 1)
        hd = getattr(self.model, "hd", None)
        n_heads = (hd.Hp if hd and hd.Hp else max(cfg.n_heads, 1))
        heads_per_slot = max(1, n_heads // self.net.n_devices)
        ccfg = cost_cfg or cfg
        # "graph" (default): the controller places the per-layer block
        # graph of the ACTUAL model depth, so its per-layer permutations
        # align 1:1 with the stacked cache/params; cost_cfg still sets the
        # pricing dims (d_model).  "columns" keeps the old aggregate lift
        # at cost_cfg's layer count.
        n_l = cfg.n_layers if layer_mode == "graph" else ccfg.n_layers
        # MoE archs: the controller places per-expert blocks (router-load-
        # weighted compute, weight-only migration bytes) when the expert
        # count tiles the mesh; otherwise the cost model stays expert-
        # oblivious (dense ffn block) rather than emitting perms that
        # cannot be physically applied to the weight stacks.
        n_exp = cfg.n_experts if (cfg.is_moe and cfg.n_experts >= 2
                                  and cfg.n_experts
                                  % self.net.n_devices == 0) else 0
        self.cost = CostModel(d_model=ccfg.d_model, n_heads=max(cfg.n_heads, 1),
                              L0=8, n_layers=max(n_l, 1), lam=lam,
                              compute_mode="incremental",
                              layer_mode=layer_mode,
                              n_experts=n_exp,
                              d_ff=(ccfg.d_ff if n_exp else 0),
                              page_size=max(0, int(cost_page_size)))
        # KV-group size: GQA stacks migrate whole groups (query heads move
        # with their shared KV head), so the controller emits
        # group-consistent permutations — the old silent skip is gone.
        # With replicated KV (hd.rep > 1: tp > n_kv_heads) the unit is the
        # SUPERGROUP Hp // Kp — all query heads of one un-replicated KV
        # head move together, so the Kp-row kv weights stay permutable and
        # the KvE replicated cache rows follow via ``expand_kv_perms``.
        # For rep == 1 this is exactly hd.groups (Hp // KvE), unchanged.
        # Geometry must divide at CONSTRUCTION (never mid-serve): the
        # bridge's head-position space is n_devices·heads_per_slot wide and
        # group blocks must tile it exactly.
        group = (hd.Hp // hd.Kp) if hd and hd.Hp and hd.Kp else 1
        if group > 1 and ((self.net.n_devices * heads_per_slot) % group
                          or max(cfg.n_heads, 1) % group):
            raise UnsupportedArchError(
                f"{cfg.name}: KV group size {group} does not tile the "
                f"{self.net.n_devices}x{heads_per_slot} head-slot geometry "
                f"— pick a device count whose head positions are a "
                f"multiple of the group size")
        self.controller = IntervalController(
            max(cfg.n_heads, 1), self.cost, self.net,
            ControllerConfig(lam=lam, heads_per_slot=heads_per_slot,
                             group_size=group,
                             pipeline_k=self.pipeline_k,
                             search=self.search))
        self.monitor = HeartbeatMonitor(self.net.n_devices)
        self.lam = lam
        self.decode_steps = 0
        self.migration_log: List[dict] = []
        # Hot-path jits DONATE their state argument: the decode state's
        # KV cache is then input/output-aliased by XLA instead of
        # materializing a second full cache every step — the cache is
        # exactly the per-device memory Algorithm 1 partitions, so an
        # undonated buffer silently doubles it.  The HLO pass of
        # ``python -m repro.analysis`` asserts the aliasing (and zero
        # full-cache parameter copies) on the optimized decode HLO; the
        # caller contract is that every donated state is dead after the
        # call (all call sites reassign, see step()/_admit()).
        self._decode_jit = jax.jit(self.model.decode_step,
                                   donate_argnums=(1,))
        self._prefill_jit = jax.jit(self.model.prefill,
                                    donate_argnums=(1,))
        # sampler: one fresh fold_in key per _sample call — the post-prefill
        # sample and the first post-decode sample can no longer collide on
        # the same PRNGKey(decode_steps) counter value.
        self._sample_base = jax.random.PRNGKey(seed + 0x5EED)
        self.sample_count = 0
        # bounded: one entry per non-greedy sample would otherwise grow for
        # the life of a long-running engine (observability, read by tests)
        self.sample_key_log: Deque[tuple] = collections.deque(maxlen=4096)

    # ---------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        req = Request(self._rid, np.asarray(prompt, np.int32),
                      max_new_tokens, t_submit=time.monotonic())
        self._rid += 1
        self.queue.append(req)
        return req.rid

    # --------------------------------------------------------------- sampler
    def _next_sample_key(self):
        key = jax.random.fold_in(self._sample_base, self.sample_count)
        self.sample_count += 1
        try:
            data = jax.random.key_data(key)
        except TypeError:            # legacy uint32 keys
            data = key
        self.sample_key_log.append(tuple(np.asarray(data).ravel().tolist()))
        return key

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        return np.asarray(jax.random.categorical(self._next_sample_key(),
                                                 logits))

    # -------------------------------------------------------------- streaming
    def _emit_token(self, req: Request, tok: int):
        """Append one generated token and fire the stream hook — the ONE
        place tokens enter a request, so every scheduler path (admission
        sample, decode step, wave loop) streams identically."""
        req.out_tokens.append(tok)
        if self.token_sink is not None:
            self.token_sink(req, tok, False)

    def _emit_done(self, req: Request):
        if self.token_sink is not None:
            self.token_sink(req, None, True)

    # ------------------------------------------------------------- telemetry
    def _record_step(self, dt: float):
        # only live devices heartbeat: a failed device must stay silent in
        # the monitor (its availability estimate is pinned at zero) until
        # rejoin_device revives it
        for j in self.net.active_ids:
            self.monitor.record_step(j, dt)

    def _load_signal(self) -> tuple:
        """(observed arrival rate, queue depth) since the last interval:
        arrivals per *scheduler step* — clock-free, so it is identical on
        virtual and wall clocks — plus the current backlog.  Resets the
        marks, so each interval reports its own window."""
        steps = self.decode_steps - self._load_mark_step
        arrived = self._rid - self._load_mark_rid
        self._load_mark_step = self.decode_steps
        self._load_mark_rid = self._rid
        return arrived / max(steps, 1), len(self.queue)

    # --------------------------------------------------------------- interval
    def _interval_plan(self, tau_tokens: Optional[int] = None) -> dict:
        """Observe -> Algorithm 1: one migration plan per interval.
        ``tau_tokens`` anchors the cost model to the observed decode stream
        (mean slot occupancy, in tokens — in-flight depth never changes
        this conversion, only the *cadence* at which intervals fire).
        The observed arrival rate and queue depth ride along into the
        interval record, so the controller sees LOAD, not just occupancy
        (the honest signal traffic-adaptive search will consume)."""
        self.net.step_background_load()
        # close the fault-tolerance loop: C_j(τ) comes from the heartbeat
        # monitor's step-time EWMAs scaling the background-load estimate.
        # Uniform step times leave the estimate untouched (ratio 1), so a
        # churn-free run observes exactly what direct observation would;
        # hung/failed slots estimate to zero.
        self.controller.observe_monitor(self.monitor,
                                        peak_flops=self.net.compute_avail)
        rate, depth = self._load_signal()
        return self.controller.step_interval(tau=self._tau_of(tau_tokens),
                                             arrival_rate=rate,
                                             queue_depth=depth)

    def _tau_of(self, tau_tokens: Optional[int]) -> Optional[int]:
        """Occupancy (tokens) -> interval index τ of the cost model."""
        if tau_tokens is None:
            return None
        return max(1, round((tau_tokens - self.cost.L0)
                            / max(self.cost.lam, 1)))

    def _migrate_state(self, state, plan, permute_params: bool = True):
        """Execute ``plan`` physically on one decode state: permute weights
        AND caches by the same (group-consistent) per-layer head
        permutations — attention is permutation-equivariant over heads
        (GQA: over whole KV groups) within each layer, so the model
        function is invariant while the placement changes
        (placement_bridge).  Returns (state, applied, reason): every
        not-applied path is reported, never silently skipped.

        ``permute_params=False`` skips the (shared) weight permutation —
        callers holding several decode states for one set of params (the
        pipelined engine's in-flight groups) permute weights exactly once
        per plan."""
        hd = getattr(self.model, "hd", None)
        if hd is None or not hd.Hp:
            return state, False, "model has no addressable attention heads"
        # Migration granularity: the supergroup Hp // Kp (== hd.groups for
        # rep == 1).  For replicated KV (rep > 1) the controller's perms
        # are supergroup-consistent, so q-side weights permute by head
        # rows, kv-side weights by the induced Kp-row permutation, and the
        # KvE replicated cache rows by its rep-expansion — every replica
        # moves with its KV head, which is what makes rep>1 plans
        # applicable at all (they used to be reported-but-skipped).
        G = hd.Hp // hd.Kp if hd.Kp else 1
        rep = hd.rep
        cache = state.get("cache")
        if not (isinstance(cache, dict) and "k" in cache
                and cache["k"].ndim >= 4):
            return state, False, "state has no addressable KV cache"
        from repro.core.placement_bridge import (
            apply_layer_head_perms, expand_kv_perms, kv_group_perms,
            permute_model_heads, permute_model_heads_layers, relative_perms)
        rel = relative_perms(plan["prev_perms"], plan["perms"])
        # per-layer rows only map onto a cache whose LEADING axis is the
        # layer stack (dense (L,B,T,KvE,dh)); grouped stacks (VLM
        # (G,4,...)) must not be reshaped against n_layers
        per_layer = rel.shape[0] > 1 and cache["k"].ndim >= 5 \
            and cache["k"].shape[0] == rel.shape[0]
        new = dict(cache)
        if per_layer:
            # row l migrates layer l independently
            if permute_params:
                self.params = permute_model_heads_layers(self.params, rel,
                                                         group_size=G)
            new["k"], new["v"] = apply_layer_head_perms(
                cache["k"], cache["v"], rel,
                layer_axis=0, head_axis=-2, group_size=G, rep=rep)
            if "k_sc" in cache:   # int8 KV: per-(token,head) scales
                new["k_sc"], new["v_sc"] = apply_layer_head_perms(
                    cache["k_sc"], cache["v_sc"], rel,
                    layer_axis=0, head_axis=-1, group_size=G, rep=rep)
            return dict(state, cache=new), True, None
        if rel.shape[0] == 1 or bool(np.all(rel == rel[0])):
            # one layout for every layer: global permutation broadcasts
            # over any leading stack axes (dense AND VLM (G,4,...))
            if G > 1:
                kv_rows = kv_group_perms(rel[:1], G)
                if rep > 1:
                    kv_rows = expand_kv_perms(kv_rows, rep)
                rkv = jnp.asarray(kv_rows[0])
            else:
                rkv = jnp.asarray(rel[0])
            if permute_params:
                self.params = permute_model_heads(self.params, rel[0],
                                                  group_size=G)
            new["k"] = jnp.take(cache["k"], rkv, axis=-2)
            new["v"] = jnp.take(cache["v"], rkv, axis=-2)
            if "k_sc" in cache:
                new["k_sc"] = jnp.take(cache["k_sc"], rkv, axis=-1)
                new["v_sc"] = jnp.take(cache["v_sc"], rkv, axis=-1)
            out = dict(state, cache=new)
            if "img_kv" in state:
                # VLM static image K/V follow their (permuted) cross-attn
                # projections
                img = state["img_kv"]
                out["img_kv"] = dict(img,
                                     k=jnp.take(img["k"], rkv, axis=-2),
                                     v=jnp.take(img["v"], rkv, axis=-2))
            return out, True, None
        # per-layer plan on a cache layout we cannot address per layer
        return state, False, \
            "per-layer plan on a cache without a leading layer axis"

    def _feed_expert_loads(self, states: Sequence[Dict[str, Any]]):
        """Average the decode states' router-load EWMAs ((L, E) routed-token
        fractions), normalize rows to sum 1, and hand them to the
        controller's expert cost model — the live-load feedback edge of the
        expert block graph.  No-op for expert-oblivious cost models."""
        if not self.cost.n_experts:
            return
        loads = [np.asarray(st["expert_load"]) for st in states
                 if isinstance(st, dict) and "expert_load" in st]
        if not loads:
            return
        rows = np.mean(loads, axis=0)
        rows = rows / np.maximum(rows.sum(axis=-1, keepdims=True), 1e-9)
        self.controller.update_expert_loads(rows)

    def _migrate_experts(self, plan) -> tuple:
        """Execute the plan's expert migrations physically: permute the
        w_gate/w_up/w_down expert rows (and the owner/share maps that ride
        with them) by the per-layer relative permutations — weight-only,
        exactly as head migrations permute cache rows.  Params are shared
        across decode states, so this runs ONCE per plan.  Returns
        (applied, reason)."""
        if plan.get("prev_expert_perms") is None \
                or not plan.get("expert_migrations"):
            return False, None
        moe = self.params.get("layers", {})
        if not (isinstance(moe, dict) and "moe" in moe
                and "owner" in moe["moe"]):
            return False, "params carry no physical expert rows"
        from repro.core.placement_bridge import (
            permute_model_experts_layers, relative_perms)
        rel = relative_perms(plan["prev_expert_perms"], plan["expert_perms"])
        L = int(moe["moe"]["owner"].shape[0])
        if rel.shape[0] == 1:
            rel = np.broadcast_to(rel, (L, rel.shape[1]))
        if rel.shape[0] != L:
            return False, ("expert plan rows do not match the stacked "
                           "expert weights")
        self.params = permute_model_experts_layers(self.params, rel)
        return True, None

    def _interval(self, state, tau_tokens: Optional[int] = None):
        """The paper's controller interval: observe -> Algorithm 1 ->
        migrate head shards (and expert weight rows) in the decode gap."""
        self._feed_expert_loads([state])
        plan = self._interval_plan(tau_tokens)
        applied, reason = False, None
        if plan["migrations"]:
            state, applied, reason = self._migrate_state(state, plan)
        e_applied, e_reason = self._migrate_experts(plan)
        self._log_interval(plan, applied, reason, e_applied, e_reason)
        return state

    # ------------------------------------------------- migration pricing
    def _live_cache_tokens(self) -> int:
        """KV tokens a migration actually moves, summed over slots: dense
        engines hold (and must copy) the full reserved
        ``n_slots × max_seq`` extent per kv row.  The paged engine
        overrides this with its allocated page count — the measurable
        difference behind pages-as-the-migration-unit."""
        return self.n_slots * self.max_seq

    def _migration_bytes(self, pairs) -> int:
        """Bytes the plan's head migrations move through the cache: one
        k+v row over the live token extent per distinct migrated
        (layer, kv group) — ×rep replicas, +f32 scales for int8 KV."""
        hd = getattr(self.model, "hd", None)
        if hd is None or not hd.Hp or not pairs:
            return 0
        G = hd.Hp // hd.Kp if hd.Kp else 1
        kv_moves = {(l, h // G) for (l, h, _s, _d) in pairs}
        tokens = self._live_cache_tokens()
        if self.cfg.kv_quant:
            per_row = tokens * 2 * (hd.dh + 4)   # int8 k+v + f32 scales
        else:
            per_row = tokens * 2 * hd.dh * \
                jnp.dtype(self.cfg.dtype).itemsize
        return int(len(kv_moves) * hd.rep * per_row)

    def _expert_migration_bytes(self, pairs) -> int:
        """Bytes the plan's expert migrations move: 3·D·F weights per
        distinct migrated (layer, expert row) — weight-only, no KV term
        (Table I's expert column; the paper's m_i for experts)."""
        if not pairs:
            return 0
        moves = {(l, e) for (l, e, _s, _d) in pairs}
        D = self.cfg.d_model
        F = self.cfg.d_ff or 4 * D
        per = 3 * D * F * jnp.dtype(self.cfg.param_dtype).itemsize
        return int(len(moves) * per)

    def _log_interval(self, plan, applied: bool, reason: Optional[str],
                      expert_applied: bool = False,
                      expert_reason: Optional[str] = None):
        epairs = plan.get("expert_migrations") or []
        self.migration_log.append({
            "step": self.decode_steps,
            "arrival_rate": plan.get("arrival_rate"),
            "queue_depth": plan.get("queue_depth"),
            "n_migrations": len(plan["migrations"]),
            "mig_bytes": self._migration_bytes(plan["migrations"]),
            "n_expert_migrations": len(epairs),
            "expert_mig_bytes": self._expert_migration_bytes(epairs),
            "d_mig_est": plan["d_mig_est"],
            "d_pipe_est": plan.get("d_pipe_est"),
            "applied": applied, "reason": reason,
            "expert_applied": expert_applied,
            "expert_reason": expert_reason})


class ServingEngine(_EngineBase):
    """Continuous-batching scheduler: persistent per-slot KV cache, admit-
    on-free-slot, bucketed prefill, per-slot decode masking.

    ``pipeline_k`` > 1 keeps K decode tokens in flight across slot groups
    (micro-batched decode pipelining, Model-Distributed Inference style):
    the slots are partitioned into K contiguous groups with independent
    decode states, and each scheduler step advances ONE group — while
    group g's token transits the later layer stages, groups g+1..K-1 issue
    theirs into the earlier stages.  In-flight depth is bounded by slot
    occupancy (an empty group is a pipeline bubble, it cannot carry a
    token), and the controller's migration cadence scales by K: a slot
    generates one token every K steps, so λ tokens per slot = λ·K
    scheduler steps (the interval accounting stays token-denominated).

    VLM configs are slot-wired: ``submit`` takes per-request image patch
    embeddings, prefill projects them into the request's static image K/V,
    and ``insert_slot`` splices img_kv/img_mask rows alongside the cache.

    ``paged=True`` swaps the dense per-slot cache for the paged KV
    subsystem (serving.paging): a pooled page store per decode group, a
    per-slot page table, pages allocated as decode advances and freed on
    retire, and CHUNKED prefill through one fixed-shape jit (the bucket
    ladder disappears — ``prefill_chunk`` tokens per chunk, traced
    row/start/length).  ``kv_pages`` bounds the pool (the per-device
    memory budget knob: a smaller pool admits the same slots because
    they only hold live pages); migrations move only live pages and the
    controller prices cache memory page-granularly
    (``CostModel.page_size``).
    """

    def __init__(self, cfg: ModelConfig, *,
                 buckets: Optional[Sequence[int]] = None,
                 img_tokens: int = 16, paged: bool = False,
                 page_size: int = 64, kv_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None, **kw):
        # cheap cfg-only check BEFORE params/controller are built; the
        # served extent decides whether a sliding-window arch stays linear
        reason = supports_continuous(cfg, kw.get("max_seq", 512))
        if reason is not None:
            raise UnsupportedArchError(reason + "; use WaveServingEngine")
        self.paged = bool(paged)
        if self.paged:
            if cfg.family == "vlm":
                raise UnsupportedArchError(
                    "paged KV does not yet carry the VLM image K/V; "
                    "use paged=False")
            # the controller prices cache memory (and so migration bytes)
            # at page granularity — what the allocator actually hands out
            kw.setdefault("cost_page_size", page_size)
        super().__init__(cfg, **kw)
        assert hasattr(self.model, "prefill_bucketed"), type(self.model)
        if self.n_slots % self.pipeline_k:
            raise ValueError(f"n_slots={self.n_slots} must be divisible by "
                             f"pipeline_k={self.pipeline_k}")
        if self.pipeline_k > 1 and not self.greedy:
            raise ValueError("pipeline_k > 1 requires greedy decoding "
                             "(host-side sampling would serialize groups)")
        self.rows_per_group = self.n_slots // self.pipeline_k
        self.buckets = sorted(set(buckets)) if buckets \
            else default_buckets(self.max_seq)
        self.is_vlm = cfg.family == "vlm"
        self.img_tokens = img_tokens
        if self.paged:
            if self.max_seq % page_size:
                raise ValueError(f"max_seq={self.max_seq} must be a "
                                 f"multiple of page_size={page_size}")
            from repro.serving.paging import PagedKVAllocator
            self.page_size = int(page_size)
            self.pages_per_slot = self.max_seq // self.page_size
            # pool size per decode group: default = full dense reservation
            # (paged is then a pure refactor); a SMALLER pool is the
            # memory-budget knob — the same device bytes admit more slots
            # because slots only hold their live pages
            self.kv_pages = int(kv_pages) if kv_pages is not None \
                else self.rows_per_group * self.pages_per_slot
            self.allocators = [
                PagedKVAllocator(self.kv_pages, self.page_size,
                                 self.rows_per_group, self.pages_per_slot)
                for _ in range(self.pipeline_k)]
            # one fixed chunk shape = ONE prefill lowering, period
            self.prefill_chunk = int(prefill_chunk or self.page_size)
        # kernelized decode: per-layer gather maps (physical q-head rows in
        # slot-grouped placement order) threaded through the decode state.
        # VLM caches are (G, 4, ...) stacks migrated all-layers-equal, so
        # the identity maps the model defaults to stay correct there.
        self._rows_layers = 0
        if self.use_kernel and not self.is_vlm:
            hd = self.model.hd
            width = self.net.n_devices * self.controller.cfg.heads_per_slot
            if width != hd.Hp:
                raise UnsupportedArchError(
                    f"use_kernel: the bridge's {self.net.n_devices}x"
                    f"{self.controller.cfg.heads_per_slot} head-position "
                    f"space must equal the model's {hd.Hp} padded heads "
                    f"for placement-derived kernel grids")
            from repro.core.placement_bridge import identity_head_rows
            self._rows_layers = cfg.n_layers
            self._head_rows, self._head_inv = identity_head_rows(
                self._rows_layers, hd.Hp)
            self._phys_perms = None   # layout actually applied to weights
        self.states: List[Dict[str, Any]] = [
            self._attach_head_rows(self._fresh_state(self.rows_per_group))
            for _ in range(self.pipeline_k)]
        self.slots: List[Optional[Request]] = [None] * self.n_slots
        self._next = np.zeros(self.n_slots, np.int32)
        # donate like _decode_jit: the bucketed sub-state and the spliced
        # slot state are dead after each call (reassigned in _admit)
        self._prefill_bucketed_jit = jax.jit(self.model.prefill_bucketed,
                                             donate_argnums=(1,))
        self._insert_jit = jax.jit(self.model.insert_slot,
                                   donate_argnums=(0,))
        if self.paged:
            # chunked prefill + page-table mount: row/start/length are
            # traced scalars, so each is ONE lowering for all slots,
            # chunks, and prompt lengths (the HLO audit gates this)
            self._paged_prefill_jit = jax.jit(self.model.prefill_paged,
                                              donate_argnums=(1,))
            self._mount_jit = jax.jit(self.model.mount_slot_pages,
                                      donate_argnums=(0,))
        # observability: scheduler decisions + compile boundedness (bounded,
        # like sample_key_log: a serving loop must not grow per request)
        self.admission_log: Deque[dict] = \
            collections.deque(maxlen=4096)    # {step, slot, rid, bucket}
        self.prefill_buckets_used: set = set()
        self.slot_busy_steps = 0              # sum of active slots per step
        # elastic churn: recovery events (fail/rejoin) with their replay
        # accounting, plus the client-visible tokens dropped by recovery
        # (teacher-forced replay re-derives every stream, so this stays 0
        # unless a future recovery path chooses to shed work)
        self.recovery_log: List[dict] = []
        self.tokens_lost = 0
        self._replan_pending = False

    def _fresh_state(self, batch: int, max_seq: Optional[int] = None,
                     img: Optional[np.ndarray] = None,
                     img_mask: Optional[np.ndarray] = None):
        if self.paged:
            return self.model.init_paged_state(
                self.params, batch, self.kv_pages, self.page_size,
                self.pages_per_slot)
        kw: Dict[str, Any] = {"per_slot": True}
        if self.is_vlm:
            # fixed-size image K/V buffer; empty rows are fully masked and
            # project zero K/V, so imageless slots attend to nothing
            kw["img_embeds"] = jnp.zeros(
                (batch, self.img_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype)) if img is None \
                else jnp.asarray(img)
            kw["img_mask"] = jnp.zeros((batch, self.img_tokens), jnp.bool_) \
                if img_mask is None else jnp.asarray(img_mask)
        return self.model.init_decode_state(
            self.params, batch, max_seq or self.max_seq, **kw)

    # ----------------------------------------------------- kernel row maps
    def _attach_head_rows(self, state: Dict[str, Any]) -> Dict[str, Any]:
        if not self._rows_layers:
            return state
        return dict(state, head_rows=jnp.asarray(self._head_rows),
                    head_inv=jnp.asarray(self._head_inv))

    def _refresh_head_rows(self, plan: dict):
        """Rebuild the kernel gather maps from the controller's plan: the
        resident slices come from the BlockGraph placement
        (``placement_to_head_slices`` via ``head_row_maps``) mapped
        through the physical layout actually applied to weights/caches —
        after a migration the maps MUST be rebuilt or the grid would
        dispatch stale rows.  Row maps are data (same shape every
        interval), so no decode recompile happens."""
        if not self._rows_layers:
            return
        from repro.core.placement_bridge import head_row_maps
        self._head_rows, self._head_inv = head_row_maps(
            plan["place"], self.controller.blocks, self.net.n_devices,
            self.model.hd.Hp, perms=self._phys_perms)
        if self._rows_layers != self._head_rows.shape[0]:
            # columns-mode controller: one row for every model layer
            self._head_rows = np.broadcast_to(
                self._head_rows[0], (self._rows_layers,
                                     self._head_rows.shape[1])).copy()
            self._head_inv = np.broadcast_to(
                self._head_inv[0], self._head_rows.shape).copy()
        self.states = [self._attach_head_rows(st) for st in self.states]

    # ------------------------------------------------------------- geometry
    @property
    def state(self) -> Dict[str, Any]:
        """The decode state (single-group engines only — pipelined engines
        hold one state per in-flight group in ``states``)."""
        assert self.pipeline_k == 1, "pipelined engine: use .states[g]"
        return self.states[0]

    def _group_of(self, slot: int) -> tuple:
        return slot // self.rows_per_group, slot % self.rows_per_group

    # ---------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               img_embeds: Optional[np.ndarray] = None) -> int:
        """``img_embeds`` (I, d_model), I <= ``img_tokens``: VLM image
        patch embeddings for this request (right-padded + masked into the
        engine's fixed image buffer).  Rejected at intake, not mid-run."""
        self._bucket(len(np.asarray(prompt)))   # reject over-long at intake
        if img_embeds is not None and not self.is_vlm:
            raise ValueError(f"{self.cfg.name} is not a VLM: it takes no "
                             f"image embeddings")
        rid = super().submit(prompt, max_new_tokens)
        if self.is_vlm:
            req = self.queue[-1]
            img = np.zeros((self.img_tokens, self.cfg.d_model), np.float32)
            mask = np.zeros((self.img_tokens,), bool)
            if img_embeds is not None:
                img_embeds = np.asarray(img_embeds)
                n = img_embeds.shape[0]
                if img_embeds.ndim != 2 or n > self.img_tokens \
                        or img_embeds.shape[1] != self.cfg.d_model:
                    raise ValueError(
                        f"img_embeds must be (I<={self.img_tokens}, "
                        f"{self.cfg.d_model}), got {img_embeds.shape}")
                img[:n] = img_embeds
                mask[:n] = True
            req.img, req.img_mask = img, mask
        return rid

    # ------------------------------------------------------------- scheduler
    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(f"prompt length {n} exceeds max bucket "
                         f"{self.buckets[-1]}")

    def _retire(self, slot: int):
        r = self.slots[slot]
        r.done = True
        r.t_done = time.monotonic()
        self.finished.append(r)
        self.slots[slot] = None
        self._next[slot] = 0
        if self.paged:
            # free the slot's pages and unmount its table row: the row's
            # future (clamped) writes drop and its reads are masked, so
            # recycled pages cannot be corrupted by a retired slot
            g, row = self._group_of(slot)
            self.allocators[g].release(row)
            self.states[g] = self._mount_jit(
                self.states[g], jnp.int32(row),
                jnp.asarray(self.allocators[g].page_map_row(row)),
                jnp.int32(0))
        self._emit_done(r)

    def _finish_check(self, slot: int):
        r = self.slots[slot]
        if (len(r.out_tokens) >= r.max_new_tokens
                or len(r.prompt) + len(r.out_tokens) >= self.max_seq - 1):
            self._retire(slot)

    def _admit(self):
        """Fill every free slot from the queue (FIFO, any prompt length).
        Loops until no slot is free — a request that retires at admission
        (1-token budget) frees its slot for the next queued request."""
        while self.queue:
            s = next((i for i in range(self.n_slots)
                      if self.slots[i] is None), None)
            if s is None:
                return
            if self.paged:
                if not self._admit_paged(s):
                    return      # head-of-line: wait for pages to free
                continue
            r = self.queue.pop(0)
            L0 = len(r.prompt)
            Lb = self._bucket(L0)
            toks = np.zeros((1, Lb), np.int32)
            toks[0, :L0] = r.prompt
            sub = self._fresh_state(
                1, Lb,
                img=None if r.img is None else r.img[None],
                img_mask=None if r.img_mask is None else r.img_mask[None])
            logits, sub = self._prefill_bucketed_jit(
                self.params, sub, jnp.asarray(toks),
                jnp.asarray([L0], jnp.int32))
            self.prefill_buckets_used.add(Lb)
            g, row = self._group_of(s)
            self.states[g] = self._insert_jit(self.states[g], sub, row)
            r.t_first = time.monotonic()
            self.slots[s] = r
            # rpr: ignore[RPR004] -- the admission-time sample IS the
            # scheduler's sync point: the first token must reach the host
            # to seed _next before the slot can decode
            tok = int(self._sample(logits)[0])
            self._next[s] = tok
            self._emit_token(r, tok)
            self.admission_log.append({"step": self.decode_steps, "slot": s,
                                       "rid": r.rid, "bucket": Lb})
            self._finish_check(s)

    def _admit_paged(self, s: int) -> bool:
        """Admit the queue head into free slot ``s``: reserve its
        worst-case page footprint (prompt + its own decode budget — so
        decode-time extension can never exhaust the pool mid-stream),
        allocate the prompt's pages, mount the table row, and run the
        prompt through the SINGLE fixed-shape chunked-prefill jit.
        Returns False when the pool cannot reserve yet (head-of-line
        wait: the request admits once running slots retire)."""
        r = self.queue[0]
        L0 = len(r.prompt)
        g, row = self._group_of(s)
        alloc = self.allocators[g]
        horizon = min(L0 + r.max_new_tokens + 1, self.max_seq)
        if not alloc.can_admit(L0, horizon):
            return False
        self.queue.pop(0)
        pages = alloc.admit(row, n_tokens=L0, horizon=horizon)
        self.states[g] = self._mount_jit(
            self.states[g], jnp.int32(row),
            jnp.asarray(alloc.page_map_row(row)), jnp.int32(0))
        C = self.prefill_chunk
        logits = None
        for c0 in range(0, max(L0, 1), C):
            n = min(C, L0 - c0)
            toks = np.zeros((1, C), np.int32)
            toks[0, :n] = r.prompt[c0:c0 + n]
            logits, self.states[g] = self._paged_prefill_jit(
                self.params, self.states[g], jnp.asarray(toks),
                jnp.int32(row), jnp.int32(c0), jnp.int32(n))
        self.prefill_buckets_used.add(C)
        r.t_first = time.monotonic()
        self.slots[s] = r
        # rpr: ignore[RPR004] -- the admission-time sample IS the
        # scheduler's sync point: the first token must reach the host
        # to seed _next before the slot can decode
        tok = int(self._sample(logits)[0])
        self._next[s] = tok
        self._emit_token(r, tok)
        self.admission_log.append({"step": self.decode_steps, "slot": s,
                                   "rid": r.rid, "bucket": C,
                                   "pages": len(pages)})
        self._finish_check(s)
        return True

    def _ensure_pages(self, g: int, active: List[int], lo: int):
        """Lazy page growth: before group ``g`` decodes, any slot whose
        next write position crosses into an unallocated page draws one
        from its admission reservation and remounts its table row —
        live bytes track actual depth, not the reservation."""
        alloc = self.allocators[g]
        for s in active:
            row = s - lo
            r = self.slots[s]
            write_pos = len(r.prompt) + len(r.out_tokens) - 1
            if write_pos >= alloc.pages_for(row) * self.page_size:
                alloc.extend(row, write_pos + 1)
                self.states[g] = self._mount_jit(
                    self.states[g], jnp.int32(row),
                    jnp.asarray(alloc.page_map_row(row)),
                    jnp.int32(write_pos))

    def _live_cache_tokens(self) -> int:
        """Paged engines move only allocated pages (page-rounded live
        tokens, summed over groups) when a head's cache migrates."""
        if not self.paged:
            return super()._live_cache_tokens()
        return sum(a.live_pages for a in self.allocators) * self.page_size

    def _active(self) -> List[int]:
        return [s for s in range(self.n_slots) if self.slots[s] is not None]

    def _group_active(self, g: int) -> List[int]:
        lo = g * self.rows_per_group
        return [s for s in range(lo, lo + self.rows_per_group)
                if self.slots[s] is not None]

    def _occupancy(self) -> float:
        """Mean tokens resident per active slot (prompt + generated).
        Paged engines report page-rounded ALLOCATED tokens — the τ anchor
        then prices exactly the memory the allocator handed out."""
        act = self._active()
        if not act:
            return 0.0
        if self.paged:
            return float(np.mean(
                [self.allocators[self._group_of(s)[0]].pages_for(
                    self._group_of(s)[1]) * self.page_size for s in act]))
        return float(np.mean([len(self.slots[s].prompt)
                              + len(self.slots[s].out_tokens) for s in act]))

    def step(self) -> bool:
        """One scheduler iteration: admit into free slots, then one decode
        step for the in-flight group whose pipeline phase is due (with
        ``pipeline_k=1`` that is every active slot — the sequential path,
        unchanged).  Returns False when idle.

        An empty due group is a pipeline bubble: the step still advances
        the phase clock (in-flight depth is bounded by slot occupancy) but
        produces no tokens."""
        self._admit()
        if not self._active():
            return False
        g = self.decode_steps % self.pipeline_k
        lo = g * self.rows_per_group
        active = self._group_active(g)
        if active:
            if self.paged:
                self._ensure_pages(g, active, lo)
            t0 = time.monotonic()
            nxt = self._next[lo:lo + self.rows_per_group]
            logits, self.states[g] = self._decode_jit(
                self.params, self.states[g], jnp.asarray(nxt))
            jax.block_until_ready(logits)
            dt = time.monotonic() - t0
            toks = self._sample(logits)
        self.decode_steps += 1
        if active:
            self.slot_busy_steps += len(active)
            for s in active:
                # rpr: ignore[RPR004] -- post-block_until_ready host read:
                # the scheduler needs concrete tokens for retire/admit
                tok = int(toks[s - lo])
                self._emit_token(self.slots[s], tok)
                self._next[s] = tok
                self._finish_check(s)
            self._record_step(dt)
        # migration cadence scales with the in-flight depth: a slot emits
        # one token every pipeline_k steps, so λ tokens per slot = λ·K
        # scheduler steps — the controller fires per λ *generated* tokens,
        # matching wall-clock token output (the τ anchor itself is already
        # token-denominated via _occupancy)
        if self._replan_pending \
                or self.decode_steps % (self.lam * self.pipeline_k) == 0:
            self._replan_pending = False
            # live router loads first: this interval's expert placement is
            # priced by the decode stream's gate frequencies, not the prior
            self._feed_expert_loads(self.states)
            plan = self._interval_plan(tau_tokens=self._occupancy())
            self._apply_plan(plan)
        return True

    def _apply_plan(self, plan: dict):
        """Execute a controller plan physically on every in-flight group:
        cache/weight permutations (weights once), expert weight rows once,
        kernel gather maps, interval log.  Shared by the periodic interval
        and the churn paths (failure evacuation / rejoin expansion)."""
        applied, reason = False, None
        if plan["migrations"]:
            for i in range(self.pipeline_k):
                self.states[i], applied, reason = self._migrate_state(
                    self.states[i], plan, permute_params=(i == 0))
        if applied:
            # weights/caches now sit in the plan's layout; the kernel
            # gather maps must follow the same source of truth
            self._phys_perms = plan["perms"]
        # expert rows are weight-only state shared by all groups:
        # permute them exactly once per plan
        e_applied, e_reason = self._migrate_experts(plan)
        self._refresh_head_rows(plan)
        self._log_interval(plan, applied, reason, e_applied, e_reason)

    def run(self, max_steps: int = 10_000):
        while self.decode_steps < max_steps:
            if not self.step():
                break
        return self.finished

    # ------------------------------------------------------------- churn
    def request_replan(self):
        """Force the controller interval to fire on the next scheduler
        step regardless of the λ cadence — the async watchdog's recovery
        escalation hook (a hang must not wait out a long interval)."""
        self._replan_pending = True

    def slow_device(self, device: int, factor: float):
        """Persistent ``factor``x slowdown on ``device``: pinned load the
        monitor-fed observation surfaces at the next interval, where
        Algorithm 1 migrates away iff the move pays (§III.G)."""
        self.net.slow(device, factor)

    def fail_device(self, device: int) -> dict:
        """Device death mid-decode: evacuate, then recover bit-identically.

        The controller's evacuation plan moves the dead device's blocks to
        survivors (raising when they cannot hold them), and ``_apply_plan``
        permutes weights/caches into the new layout.  Head permutations
        always route rows *through* the dead device's cache rows, so part
        of every group's KV cache is unrecoverable — instead of shedding
        the affected requests, every in-flight stream is rebuilt by
        teacher-forced replay of its already-emitted tokens through the
        engine's own prefill/decode jits (identical ops, identical batch
        geometry => bitwise-identical cache, hence bit-identical surviving
        streams).  No client-visible token is dropped: ``tokens_lost``
        stays 0 and replay never re-emits or re-samples."""
        if not self.net.is_active(device):
            raise ValueError(f"device {device} is not active")
        self.monitor.mark_failed(device)
        self._feed_expert_loads(self.states)
        plan = self.controller.handle_failure(
            device, tau=self._tau_of(self._occupancy()))
        self._apply_plan(plan)
        stats = self._replay_groups()
        self.recovery_log.append({
            "step": self.decode_steps, "event": "fail",
            "device": int(device), "tokens_lost": 0,
            "d_mig_est": plan["d_mig_est"],
            "d_pipe_est": plan["d_pipe_est"], **stats})
        return plan

    def rejoin_device(self, device: int) -> dict:
        """A previously failed device returns (empty): the controller's
        expansion plan re-spreads blocks onto it when that pays, and
        ``_apply_plan`` executes the moves — migration copies KV rows
        from surviving sources, so rejoin needs no replay."""
        if self.net.is_active(device):
            raise ValueError(f"device {device} is already active")
        plan = self.controller.handle_rejoin(
            device, tau=self._tau_of(self._occupancy()))
        self.monitor.record_heartbeat(device)
        self._apply_plan(plan)
        self.recovery_log.append({
            "step": self.decode_steps, "event": "rejoin",
            "device": int(device),
            "n_migrations": len(plan["migrations"])})
        return plan

    # ----------------------------------------------------------- replay
    def _replay_groups(self) -> dict:
        stats = {"replay_steps": 0, "replay_prefills": 0,
                 "replayed_slots": 0}
        for g in range(self.pipeline_k):
            st = self._replay_group(g)
            for k in stats:
                stats[k] += st[k]
        return stats

    def _replay_group(self, g: int) -> dict:
        """Rebuild group ``g``'s KV cache from its slots' request records.

        Slots are re-prefilled and then teacher-forced through the SAME
        donated decode jit, in the same batch geometry, feeding each
        already-emitted token at the position that originally produced its
        successor.  Unequal depths are staggered: with n_s tokens emitted
        on slot s and N = max(n_s), slot s is inserted at tick N - n_s so
        every slot finishes together — before insertion its row decodes
        garbage exactly like a freed slot's row, which the masking tests
        prove cannot touch other rows.  Replay samples nothing and emits
        nothing: ``_next``/``sample_count``/``decode_steps`` are whatever
        live decode left them."""
        active = self._group_active(g)
        lo = g * self.rows_per_group
        if self.paged:
            # the old allocator's page map described the pre-failure cache;
            # a fresh pool re-admitted in slot order reproduces admission's
            # reservations against the rebuilt (empty) page buffer
            from repro.serving.paging import PagedKVAllocator
            self.allocators[g] = PagedKVAllocator(
                self.kv_pages, self.page_size, self.rows_per_group,
                self.pages_per_slot)
        self.states[g] = self._attach_head_rows(
            self._fresh_state(self.rows_per_group))
        out = {"replay_steps": 0, "replay_prefills": 0,
               "replayed_slots": len(active)}
        if not active:
            return out
        ns = {s: len(self.slots[s].out_tokens) for s in active}
        max_n = max(ns.values())
        for i in range(max_n):
            for s in active:
                if max_n - ns[s] == i:
                    self._replay_insert(g, s)
                    out["replay_prefills"] += 1
            if i == max_n - 1:
                break   # the last emitted token was never decoded upon
            nxt = np.zeros(self.rows_per_group, np.int32)
            for s in active:
                k = i - (max_n - ns[s])
                if k >= 0:
                    r = self.slots[s]
                    nxt[s - lo] = r.out_tokens[k]
                    if self.paged:
                        # this step writes position L0 + k for slot s
                        self._replay_extend(g, s - lo, len(r.prompt) + k)
            _, self.states[g] = self._decode_jit(
                self.params, self.states[g], jnp.asarray(nxt))
            out["replay_steps"] += 1
        return out

    def _replay_insert(self, g: int, s: int):
        """Re-run slot ``s``'s admission-time prefill (same jits, same
        chunking/bucketing) into the rebuilt group state."""
        r = self.slots[s]
        row = s - g * self.rows_per_group
        L0 = len(r.prompt)
        if self.paged:
            alloc = self.allocators[g]
            horizon = min(L0 + r.max_new_tokens + 1, self.max_seq)
            alloc.admit(row, n_tokens=L0, horizon=horizon)
            self.states[g] = self._mount_jit(
                self.states[g], jnp.int32(row),
                jnp.asarray(alloc.page_map_row(row)), jnp.int32(0))
            C = self.prefill_chunk
            for c0 in range(0, max(L0, 1), C):
                n = min(C, L0 - c0)
                toks = np.zeros((1, C), np.int32)
                toks[0, :n] = r.prompt[c0:c0 + n]
                _, self.states[g] = self._paged_prefill_jit(
                    self.params, self.states[g], jnp.asarray(toks),
                    jnp.int32(row), jnp.int32(c0), jnp.int32(n))
            return
        Lb = self._bucket(L0)
        toks = np.zeros((1, Lb), np.int32)
        toks[0, :L0] = r.prompt
        sub = self._fresh_state(
            1, Lb,
            img=None if r.img is None else r.img[None],
            img_mask=None if r.img_mask is None else r.img_mask[None])
        _, sub = self._prefill_bucketed_jit(
            self.params, sub, jnp.asarray(toks),
            jnp.asarray([L0], jnp.int32))
        self.states[g] = self._insert_jit(self.states[g], sub, row)

    def _replay_extend(self, g: int, row: int, write_pos: int):
        alloc = self.allocators[g]
        if write_pos >= alloc.pages_for(row) * self.page_size:
            alloc.extend(row, write_pos + 1)
            self.states[g] = self._mount_jit(
                self.states[g], jnp.int32(row),
                jnp.asarray(alloc.page_map_row(row)), jnp.int32(write_pos))


class WaveServingEngine(_EngineBase):
    """The old wave-based static scheduler: equal-length prompts per wave,
    lock-step decode, slots freed only when the wave drains.  Kept as the
    baseline for ``benchmarks/serving_throughput.py``."""

    def _next_wave(self) -> List[Request]:
        """Up to n_slots queued requests with equal prompt length."""
        if not self.queue:
            return []
        L0 = len(self.queue[0].prompt)
        wave = [r for r in self.queue if len(r.prompt) == L0][:self.n_slots]
        for r in wave:
            self.queue.remove(r)
        return wave

    def _run_wave(self, wave: List[Request], max_steps: int):
        B = self.n_slots
        L0 = len(wave[0].prompt)
        prompts = np.zeros((B, L0), np.int32)
        for i, r in enumerate(wave):
            prompts[i] = r.prompt
        state = self.model.init_decode_state(self.params, B, self.max_seq)
        logits, state = self._prefill_jit(self.params, state,
                                          jnp.asarray(prompts))
        for r in wave:
            r.t_first = time.monotonic()
        active = {i: r for i, r in enumerate(wave)}
        nxt = self._sample(logits)
        while active and self.decode_steps < max_steps:
            for i, r in list(active.items()):
                # rpr: ignore[RPR004] -- wave scheduler's finish check
                # runs on host tokens; nxt is already device-synced
                self._emit_token(r, int(nxt[i]))
                if (len(r.out_tokens) >= r.max_new_tokens
                        or L0 + len(r.out_tokens) >= self.max_seq - 1):
                    r.done = True
                    r.t_done = time.monotonic()
                    self.finished.append(r)
                    del active[i]
                    self._emit_done(r)
            if not active:
                break
            t0 = time.monotonic()
            logits, state = self._decode_jit(self.params, state,
                                             jnp.asarray(nxt))
            jax.block_until_ready(logits)
            dt = time.monotonic() - t0
            nxt = self._sample(logits)
            self.decode_steps += 1
            self._record_step(dt)
            if self.decode_steps % self.lam == 0:
                state = self._interval(state)

    def run(self, max_steps: int = 10_000):
        while self.queue and self.decode_steps < max_steps:
            wave = self._next_wave()
            if not wave:
                break
            self._run_wave(wave, max_steps)
        return self.finished


def make_engine(cfg: ModelConfig, *, mode: str = "auto", **kw):
    """``continuous`` | ``wave`` | ``auto`` (continuous when the arch
    supports the slot API, wave otherwise)."""
    if mode == "wave":
        return WaveServingEngine(cfg, **kw)
    if mode == "continuous":
        return ServingEngine(cfg, **kw)
    try:
        return ServingEngine(cfg, **kw)
    except NotImplementedError:
        return WaveServingEngine(cfg, **kw)
