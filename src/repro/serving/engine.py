"""Batched serving engine with the paper's controller in the loop.

Wave-based static batching: up to ``n_slots`` requests with equal-length
prompts form a wave; the wave prefills as one batch, then decodes in
lock-step until every request hits its token budget.  Every λ decode steps
the IntervalController observes step-time telemetry + cache growth,
re-runs Algorithm 1, and applies any head migrations to the cache in the
inter-step gap — the paper's per-interval migration loop as a production
serving feature (straggler and memory-pressure mitigation; DESIGN.md §9).

On a single CPU host this runs unsharded (NULL partitioner) and the
controller drives a *simulated* slot network — the same code path the TPU
deployment uses with mesh slots.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.blocks import CostModel
from repro.core.controller import ControllerConfig, IntervalController
from repro.core.network import DeviceNetwork
from repro.models.api import build_model
from repro.runtime.fault_tolerance import HeartbeatMonitor


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L0,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, n_slots: int = 4,
                 max_seq: int = 512, lam: int = 16, seed: int = 0,
                 net: Optional[DeviceNetwork] = None, cost_cfg=None,
                 part=None, tp: int = 1, greedy: bool = True):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.greedy = greedy
        from repro.models.partitioning import NULL
        self.model = build_model(cfg, tp=tp, part=part or NULL)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._rid = 0
        # controller wiring (the paper's technique in the serving loop).
        # The controller's cost model can use the FULL production dims
        # (cost_cfg) while a reduced model serves on CPU — the placement
        # problem is the production one either way.
        n_dev = net.n_devices if net is not None else max(tp, 4)
        self.net = net or DeviceNetwork.sample(n_dev, seed=seed + 1)
        hd = getattr(self.model, "hd", None)
        n_heads = (hd.Hp if hd and hd.Hp else max(cfg.n_heads, 1))
        heads_per_slot = max(1, n_heads // self.net.n_devices)
        ccfg = cost_cfg or cfg
        cost = CostModel(d_model=ccfg.d_model, n_heads=max(cfg.n_heads, 1),
                         L0=8, n_layers=ccfg.n_layers, lam=lam,
                         compute_mode="incremental")
        self.controller = IntervalController(
            max(cfg.n_heads, 1), cost, self.net,
            ControllerConfig(lam=lam, heads_per_slot=heads_per_slot))
        self.monitor = HeartbeatMonitor(self.net.n_devices)
        self.lam = lam
        self.decode_steps = 0
        self.migration_log: List[dict] = []
        self._decode_jit = jax.jit(self.model.decode_step)
        self._prefill_jit = jax.jit(self.model.prefill)

    # ---------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        req = Request(self._rid, np.asarray(prompt, np.int32),
                      max_new_tokens, t_submit=time.monotonic())
        self._rid += 1
        self.queue.append(req)
        return req.rid

    def _next_wave(self) -> List[Request]:
        """Up to n_slots queued requests with equal prompt length."""
        if not self.queue:
            return []
        L0 = len(self.queue[0].prompt)
        wave = [r for r in self.queue if len(r.prompt) == L0][:self.n_slots]
        for r in wave:
            self.queue.remove(r)
        return wave

    # ----------------------------------------------------------------- decode
    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        key = jax.random.PRNGKey(self.decode_steps)
        return np.asarray(jax.random.categorical(key, logits))

    def _run_wave(self, wave: List[Request], max_steps: int):
        B = self.n_slots
        L0 = len(wave[0].prompt)
        prompts = np.zeros((B, L0), np.int32)
        for i, r in enumerate(wave):
            prompts[i] = r.prompt
        state = self.model.init_decode_state(self.params, B, self.max_seq)
        logits, state = self._prefill_jit(self.params, state,
                                          jnp.asarray(prompts))
        for r in wave:
            r.t_first = time.monotonic()
        active = {i: r for i, r in enumerate(wave)}
        nxt = self._sample(logits)
        while active and self.decode_steps < max_steps:
            for i, r in list(active.items()):
                r.out_tokens.append(int(nxt[i]))
                if (len(r.out_tokens) >= r.max_new_tokens
                        or L0 + len(r.out_tokens) >= self.max_seq - 1):
                    r.done = True
                    r.t_done = time.monotonic()
                    self.finished.append(r)
                    del active[i]
            if not active:
                break
            t0 = time.monotonic()
            logits, state = self._decode_jit(self.params, state,
                                             jnp.asarray(nxt))
            jax.block_until_ready(logits)
            dt = time.monotonic() - t0
            nxt = self._sample(logits)
            self.decode_steps += 1
            for j in range(self.net.n_devices):
                self.monitor.record_step(j, dt)
            if self.decode_steps % self.lam == 0:
                state = self._interval(state)

    def _interval(self, state):
        """The paper's controller interval: observe -> Algorithm 1 ->
        migrate head shards in the decode gap."""
        self.net.step_background_load()
        self.controller.observe(compute_avail=self.net.compute_avail)
        plan = self.controller.step_interval()
        hd = getattr(self.model, "hd", None)
        mha = hd is not None and hd.Hp and hd.KvE == hd.Hp and hd.rep == 1
        if plan["migrations"] and mha:
            # physical migration: permute weights AND cache by the same head
            # permutation — model function is invariant, placement changes
            # (placement_bridge.permute_model_heads). GQA archs migrate at
            # group granularity; this demo engine logs those without moving.
            cache = state.get("cache")
            if isinstance(cache, dict) and "k" in cache \
                    and cache["k"].ndim >= 4:
                prev = plan["prev_perm"]
                old_pos = {int(h): i for i, h in enumerate(prev)}
                rel = np.array([old_pos[int(h)] for h in plan["perm"]])
                from repro.core.placement_bridge import permute_model_heads
                self.params = permute_model_heads(self.params, rel)
                k2, v2 = (jnp.take(cache["k"], jnp.asarray(rel), axis=-2),
                          jnp.take(cache["v"], jnp.asarray(rel), axis=-2))
                state = dict(state, cache=dict(cache, k=k2, v=v2))
        self.migration_log.append({
            "step": self.decode_steps,
            "n_migrations": len(plan["migrations"]),
            "d_mig_est": plan["d_mig_est"]})
        return state

    def run(self, max_steps: int = 10_000):
        while self.queue and self.decode_steps < max_steps:
            wave = self._next_wave()
            if not wave:
                break
            self._run_wave(wave, max_steps)
        return self.finished
