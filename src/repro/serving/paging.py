"""Page-granular KV memory for the continuous-batching engine.

The dense engine backs every slot with a full ``(max_seq, ...)`` cache
row, so one long-budget request reserves worst-case memory for its whole
lifetime.  Here the cache is a pool of fixed-size pages shared by all
slots of a decode group; each slot owns an ordered list of page ids (its
page table) that grows as decode advances and is returned to the free
list when the slot retires.  The device side sees only a dense
``(n_rows, max_pages_per_slot)`` int32 page-map array (``-1`` marks an
unmapped logical page), so the jitted decode/prefill programs stay one
fixed-shape lowering regardless of which pages any slot holds.

Allocation policy: admission RESERVES the request's worst-case page count
(prompt + its own decode budget, page-rounded) so decode-time extension
can never fail mid-stream, but pages are HANDED OUT lazily as positions
are actually written — live-byte accounting (``live_pages``) therefore
reflects tokens resident, not tokens reserved, which is exactly the
number the migration cost model prices.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PageExhaustedError(RuntimeError):
    """Raised when an admission asks for more pages than the pool can
    ever reserve — typed so the engine (and tests) can distinguish
    capacity pressure from programming errors."""


class PagedKVAllocator:
    """Host-side page bookkeeping for ONE decode group's page pool.

    The allocator never touches device memory: it hands out page ids from
    a free list and the engine mirrors them into the device page map.
    """

    def __init__(self, n_pages: int, page_size: int, n_rows: int,
                 max_pages_per_slot: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError(f"need positive pool: n_pages={n_pages}, "
                             f"page_size={page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_rows = int(n_rows)
        self.max_pages_per_slot = int(max_pages_per_slot)
        # LIFO free list: retired pages are recycled hottest-first
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._pages: Dict[int, List[int]] = {}     # row -> live page ids
        self._reserved: Dict[int, int] = {}        # row -> reserved count

    # ------------------------------------------------------------ queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Pages actually holding tokens (not reservations)."""
        return sum(len(p) for p in self._pages.values())

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved.values())

    def pages_of(self, row: int) -> List[int]:
        return list(self._pages.get(row, ()))

    def pages_for(self, row: int) -> int:
        return len(self._pages.get(row, ()))

    def _need(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    def can_admit(self, n_tokens: int, horizon: int) -> bool:
        """True when the pool can reserve ``horizon`` tokens' worth of
        pages right now (the admission gate — head-of-line blocking, the
        request waits for retires rather than failing mid-decode).  Other
        rows' outstanding reservations stay untouchable: they are entitled
        to extend without ever hitting the pool limit."""
        need = max(self._need(n_tokens), 1)
        reserve = max(self._need(horizon), need)
        return reserve <= self.max_pages_per_slot and \
            reserve + self.reserved_pages <= self.free_pages

    def admit(self, row: int, n_tokens: int, horizon: int) -> List[int]:
        """Reserve ``horizon`` tokens of pages for ``row`` and allocate
        the first ``n_tokens`` worth.  Returns the allocated page ids (in
        logical order)."""
        if row in self._pages:
            raise ValueError(f"row {row} already admitted")
        need = max(self._need(n_tokens), 1)
        reserve = max(self._need(horizon), need)
        if reserve > self.max_pages_per_slot:
            raise PageExhaustedError(
                f"request needs {reserve} pages > max_pages_per_slot="
                f"{self.max_pages_per_slot}")
        if reserve + self.reserved_pages > self.free_pages:
            raise PageExhaustedError(
                f"pool exhausted: need {reserve} pages, "
                f"{self.free_pages} free of which "
                f"{self.reserved_pages} already reserved "
                f"(pool {self.n_pages})")
        pages = [self._free.pop() for _ in range(need)]
        self._pages[row] = pages
        self._reserved[row] = reserve - need
        return list(pages)

    def extend(self, row: int, n_tokens: int) -> List[int]:
        """Grow ``row`` to cover ``n_tokens`` written positions, drawing
        from its admission reservation (admission guarantees the pages
        exist, so a live stream can never see exhaustion here).  Returns
        the FULL page list."""
        if row not in self._pages:
            raise ValueError(f"row {row} not admitted")
        need = self._need(n_tokens)
        grow = need - len(self._pages[row])
        if grow > 0:
            unreserved_free = self.free_pages - self.reserved_pages
            if need > self.max_pages_per_slot or \
                    grow > self._reserved[row] + max(unreserved_free, 0):
                raise PageExhaustedError(
                    f"row {row}: cannot extend to {need} pages "
                    f"({self._reserved[row]} reserved, "
                    f"{self.free_pages} free)")
            self._pages[row].extend(self._free.pop() for _ in range(grow))
            self._reserved[row] = max(self._reserved[row] - grow, 0)
        return list(self._pages[row])

    def release(self, row: int) -> int:
        """Return all of ``row``'s pages (and reservation) to the free
        list; returns how many live pages were freed."""
        pages = self._pages.pop(row, [])
        self._reserved.pop(row, None)
        self._free.extend(reversed(pages))
        return len(pages)

    # ------------------------------------------------------ device mirror
    def page_map_row(self, row: int) -> np.ndarray:
        """``row``'s device page-map row: live page ids right-padded with
        ``-1`` sentinels to the fixed per-slot width."""
        out = np.full((self.max_pages_per_slot,), -1, np.int32)
        pages = self._pages.get(row, ())
        out[:len(pages)] = pages
        return out

    def check_invariants(self):
        """Free + live == total, no page owned twice, no page both free
        and live (the property tests call this after every op)."""
        live = [p for pages in self._pages.values() for p in pages]
        assert len(live) == len(set(live)), "page aliased between slots"
        assert not (set(live) & set(self._free)), "page both live and free"
        assert len(live) + len(self._free) == self.n_pages, \
            f"leak: {len(live)} live + {len(self._free)} free != " \
            f"{self.n_pages}"
