"""AdamW + LR schedules, written against plain pytrees (optax is not
available in this environment). Moments are f32 regardless of param dtype;
the update is applied in f32 and cast back (mixed-precision training with
master-moment semantics)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: Any                    # first moment (f32 pytree)
    nu: Any                    # second moment (f32 pytree)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: self.b2 * n + (1 - self.b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, n):
            mhat = m / bc1
            nhat = n / bc2
            delta = mhat / (jnp.sqrt(nhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
