"""Interval controller — the paper's §III.G loop, host-side.

Unifies the two runtimes:
 - simulator: DeviceNetwork snapshots drive Algorithm 1 directly;
 - TPU serving: step-time telemetry (runtime.fault_tolerance) estimates
   C_j(τ), KV-cache growth gives m_i(τ), the ICI matrix gives R_{j,k};
   Algorithm 1's placement becomes a head permutation (placement_bridge)
   and the migration plan is applied to the cache between decode steps —
   in the λ-interval slack, exactly where the paper schedules migrations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.algorithm import ResourceAwareAssigner
from repro.core.blocks import Block, CostModel, make_blocks
from repro.core.delay import migration_delay, total_delay
from repro.core.network import DeviceNetwork
from repro.core.placement_bridge import (apply_head_perm, migration_pairs,
                                         placement_to_perm)


@dataclasses.dataclass
class ControllerConfig:
    lam: int = 32                 # tokens per interval (λ)
    deadline: float = 0.2         # per-token latency budget (scoring)
    min_gain: float = 0.0         # extra migration-filter margin
    heads_per_slot: int = 2


class IntervalController:
    """Runs Algorithm 1 every λ generated tokens and emits migration plans."""

    def __init__(self, n_heads: int, cost: CostModel, net: DeviceNetwork,
                 cfg: ControllerConfig = ControllerConfig()):
        self.blocks: List[Block] = make_blocks(n_heads)
        self.cost = cost
        self.net = net
        self.cfg = cfg
        # the feasibility budget is the WHOLE interval: λ tokens at the
        # per-token deadline (conflating them made every ffn infeasible)
        self.assigner = ResourceAwareAssigner(self.blocks, cost,
                                              deadline=cfg.deadline * cfg.lam)
        self.place: Optional[np.ndarray] = None
        self.perm: Optional[np.ndarray] = None
        self.tau = 0
        self.history: List[dict] = []

    # ------------------------------------------------------------ observe
    def observe(self, compute_avail: Optional[np.ndarray] = None,
                mem_avail: Optional[np.ndarray] = None):
        if compute_avail is not None:
            self.net.compute_avail = np.asarray(compute_avail, float)
        if mem_avail is not None:
            self.net.mem_capacity = np.asarray(mem_avail, float)

    # ------------------------------------------------------------- decide
    def step_interval(self, tau: Optional[int] = None) -> dict:
        """One controller interval: assign, diff, plan migrations.

        ``tau`` lets the serving engine anchor the cost model to the
        *actual* decode stream — e.g. the mean KV-cache occupancy across
        continuous-batching slots (which sit at different depths) — instead
        of the lock-step +1-per-interval counter the simulator uses."""
        self.tau = max(1, int(tau)) if tau is not None else self.tau + 1
        prev = self.place
        place, stats = self.assigner.assign(self.net, self.tau, prev)
        if place is None:
            place = prev if prev is not None else \
                np.zeros(len(self.blocks), dtype=int)
        # objective filter: keep migrations only if they pay (paper §III.G)
        if prev is not None:
            from repro.core.delay import memory_feasible
            cur_val = total_delay(prev, place, self.blocks, self.cost,
                                  self.net, self.tau)
            for i in np.flatnonzero(place != prev):
                trial = place.copy()
                trial[i] = prev[i]
                if not memory_feasible(trial, self.blocks, self.cost,
                                       self.net, self.tau):
                    continue
                val = total_delay(prev, trial, self.blocks, self.cost,
                                  self.net, self.tau)
                if val <= cur_val - self.cfg.min_gain:
                    place, cur_val = trial, val
        n_slots = self.net.n_devices
        new_perm = placement_to_perm(place, self.blocks, n_slots,
                                     self.cfg.heads_per_slot)
        pairs = [] if self.perm is None else \
            migration_pairs(self.perm, new_perm, self.cfg.heads_per_slot)
        d_mig = migration_delay(prev, place, self.blocks, self.cost,
                                self.net, self.tau)
        plan = {"tau": self.tau, "place": place, "perm": new_perm,
                "prev_perm": self.perm, "migrations": pairs,
                "d_mig_est": d_mig, "infeasible": stats.infeasible}
        self.place, self.perm = place, new_perm
        self.history.append({"tau": self.tau, "n_migrations": len(pairs),
                             "d_mig_est": d_mig,
                             "infeasible": stats.infeasible})
        return plan

    # ---------------------------------------------------------------- act
    def apply_to_cache(self, cache_k, cache_v, plan, head_axis: int = 3):
        """Execute the migration plan on a head-expanded KV cache: a gather
        by the *relative* permutation (new layout in terms of current
        positions), which lowers to collective-permute between slots."""
        prev_perm = plan.get("prev_perm")
        if prev_perm is None or not plan["migrations"]:
            return cache_k, cache_v
        old_pos = {int(h): i for i, h in enumerate(prev_perm)}
        rel = np.array([old_pos[int(h)] for h in plan["perm"]])
        return apply_head_perm(cache_k, cache_v, rel, head_axis)
