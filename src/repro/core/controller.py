"""Interval controller — the paper's §III.G loop, host-side.

Unifies the two runtimes:
 - simulator: DeviceNetwork snapshots drive Algorithm 1 directly;
 - TPU serving: step-time telemetry (runtime.fault_tolerance) estimates
   C_j(τ), KV-cache growth gives m_i(τ), the ICI matrix gives R_{j,k};
   Algorithm 1's placement becomes a head permutation (placement_bridge)
   and the migration plan is applied to the cache between decode steps —
   in the λ-interval slack, exactly where the paper schedules migrations.

With a ``layer_mode="graph"`` cost model the controller places the full
per-layer block graph and emits **one head permutation per layer**
(``plan["perms"]``, shape (n_layers, n_slots·heads_per_slot)), so a
stacked KV cache is permuted layer-by-layer and head(l,i) can sit on a
different device than head(l',i).  ``plan["perm"]``/``plan["prev_perm"]``
remain the layer-0 rows for single-layer callers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.algorithm import ResourceAwareAssigner
from repro.core.blocks import Block, CostModel, make_blocks
from repro.core.delay import (migration_delay, pipelined_inference_delay,
                              revert_unpaying_migrations)
from repro.core.network import DeviceNetwork
from repro.core.placement_bridge import (apply_head_perm,
                                         apply_layer_head_perms,
                                         migration_pairs_layers,
                                         placement_to_expert_perms,
                                         placement_to_perms, relative_perms)


@dataclasses.dataclass
class ControllerConfig:
    lam: int = 32                 # tokens per interval (λ)
    deadline: float = 0.2         # per-token latency budget (scoring)
    min_gain: float = 0.0         # extra migration-filter margin
    heads_per_slot: int = 2
    # KV-group size (GQA: Hp // KvE query heads per KV head).  > 1 makes
    # every emitted permutation group-consistent, so grouped caches/weights
    # can physically migrate (placement_bridge.kv_group_perms).
    group_size: int = 1
    # decode tokens in flight across layer-disjoint stages; > 1 switches
    # the migration-filter objective to D_pipe(K) + D_mig and the engine
    # scales its interval cadence by K (λ stays token-denominated while a
    # scheduler step advances only 1/K of the slots).
    pipeline_k: int = 1
    # placement search mode: "rescoring" is the PR-3 path (Algorithm 1,
    # refine, filter); "bottleneck" (with pipeline_k > 1) adds the
    # bottleneck-targeted search — stage-balanced chain seed + layer-chain
    # moves aimed at the argmax resource, migrations amortized over
    # ``amortize`` intervals (baselines.ResourceAwarePolicy docstring).
    search: str = "rescoring"
    amortize: int = 16
    # physical expert rows per mesh slot (MoE archs).  0 = derive from the
    # cost model: expert_slots // n_devices (expert rows, like heads, tile
    # the mesh).  Only consulted when the cost model carries experts.
    experts_per_slot: int = 0


class IntervalController:
    """Runs Algorithm 1 every λ generated tokens and emits migration plans."""

    def __init__(self, n_heads: int, cost: CostModel, net: DeviceNetwork,
                 cfg: ControllerConfig = ControllerConfig()):
        self.n_layers = cost.n_layers if cost.layer_mode == "graph" else 1
        self.blocks: List[Block] = make_blocks(n_heads, self.n_layers,
                                               cost.n_experts,
                                               cost.expert_replicas)
        self.cost = cost
        self.net = net
        self.cfg = cfg
        self.has_experts = cost.n_experts >= 2
        self.experts_per_slot = cfg.experts_per_slot
        if self.has_experts and not self.experts_per_slot:
            self.experts_per_slot = max(1, cost.expert_slots // net.n_devices)
        # the feasibility budget is the WHOLE interval: λ tokens at the
        # per-token deadline (conflating them made every ffn infeasible)
        self.assigner = ResourceAwareAssigner(self.blocks, cost,
                                              deadline=cfg.deadline * cfg.lam)
        # bottleneck-targeted search mode: plans come from the full policy
        # (assign → refine → filter → bottleneck search) so the engine's
        # real migrations follow the steady-state objective; the default
        # "rescoring" path below stays bit-for-bit the PR-3 controller,
        # as does "bottleneck" at pipeline_k=1 (the search only exists on
        # the pipelined objective).  Unknown modes fail HERE, at
        # construction — a typo must not silently serve the rescoring
        # planner the caller opted out of.
        from repro.core.baselines import ResourceAwarePolicy
        if cfg.search not in ResourceAwarePolicy.SEARCH_MODES:
            raise ValueError(
                f"ControllerConfig.search must be one of "
                f"{ResourceAwarePolicy.SEARCH_MODES}, got {cfg.search!r}")
        self._policy = None
        if cfg.search == "bottleneck" and cfg.pipeline_k > 1:
            self._policy = ResourceAwarePolicy(
                self.blocks, cost, deadline=cfg.deadline * cfg.lam,
                pipeline_k=cfg.pipeline_k, search="bottleneck",
                amortize=cfg.amortize, min_gain=cfg.min_gain)
        self.place: Optional[np.ndarray] = None
        self.perms: Optional[np.ndarray] = None   # (n_layers, slots·hps)
        # (n_layers, slots·eps) physical expert-row layout (MoE archs)
        self.expert_perms: Optional[np.ndarray] = None
        self.tau = 0
        self.history: List[dict] = []

    @property
    def perm(self) -> Optional[np.ndarray]:
        """Layer-0 permutation (single-layer backward compatibility)."""
        return None if self.perms is None else self.perms[0]

    def head_counts(self, place: Optional[np.ndarray] = None) -> np.ndarray:
        """Heads per device, summed over layers."""
        place = self.place if place is None else place
        heads = [b.index for b in self.blocks if b.kind == "head"]
        return np.bincount(np.asarray(place)[heads],
                           minlength=self.net.n_devices)

    # ------------------------------------------------------------ observe
    def observe(self, compute_avail: Optional[np.ndarray] = None,
                mem_avail: Optional[np.ndarray] = None):
        """Feed observed instantaneous availability.  ``mem_avail`` lands
        in the network's availability field — hardware ``mem_capacity`` is
        never overwritten by an observation (the old conflation made one
        low-memory sample permanently shrink the device)."""
        if compute_avail is not None:
            obs = np.asarray(compute_avail, float)
            # an inactive device has zero availability no matter what the
            # (possibly stale) telemetry claims
            self.net.compute_avail = np.where(self.net.active, obs, 0.0)
        if mem_avail is not None:
            self.net.mem_avail = np.asarray(mem_avail, float)

    def observe_monitor(self, monitor, peak_flops=None):
        """Close the fault_tolerance loop: per-slot step-time EWMAs from a
        ``HeartbeatMonitor`` become the C_j(τ) estimates Algorithm 1
        reads.  Slot j maps to device j (the engine's convention).  Dead
        slots estimate to zero; devices already failed in the network stay
        at zero regardless of telemetry."""
        peak = self.net.compute_max if peak_flops is None else peak_flops
        self.observe(compute_avail=monitor.availability(peak))

    def update_expert_loads(self, loads):
        """Feed observed router loads (rows: per layer, one entry per
        physical expert slot, each row summing to ~1) into the expert cost
        model.  The assigner/policy are rebuilt around the new CostModel so
        the *next* ``step_interval`` prices expert compute and placement by
        the live gate frequencies — the engine calls this each interval
        with the decode state's router-load EWMA."""
        if not self.has_experts:
            return
        self.cost = self.cost.with_expert_loads(loads)
        self.assigner = ResourceAwareAssigner(
            self.blocks, self.cost,
            deadline=self.cfg.deadline * self.cfg.lam)
        if self._policy is not None:
            from repro.core.baselines import ResourceAwarePolicy
            self._policy = ResourceAwarePolicy(
                self.blocks, self.cost,
                deadline=self.cfg.deadline * self.cfg.lam,
                pipeline_k=self.cfg.pipeline_k, search="bottleneck",
                amortize=self.cfg.amortize, min_gain=self.cfg.min_gain)

    # ------------------------------------------------------------- decide
    def step_interval(self, tau: Optional[int] = None,
                      arrival_rate: Optional[float] = None,
                      queue_depth: Optional[int] = None) -> dict:
        """One controller interval: assign, diff, plan migrations.

        ``tau`` lets the serving engine anchor the cost model to the
        *actual* decode stream — e.g. the mean KV-cache occupancy across
        continuous-batching slots (which sit at different depths) — instead
        of the lock-step +1-per-interval counter the simulator uses.

        ``arrival_rate`` (requests per scheduler step since the last
        interval) and ``queue_depth`` (backlog at the interval boundary)
        are the engine's observed LOAD — recorded into the plan and
        history so the controller's view covers the arrival process, not
        just resident occupancy.  Today they are telemetry; they are the
        input the traffic-adaptive search (ROADMAP) will act on."""
        self.tau = max(1, int(tau)) if tau is not None else self.tau + 1
        prev = self.place
        k = self.cfg.pipeline_k
        if self._policy is not None:
            # bottleneck mode: the policy already refines, filters (with
            # min_gain) and runs the bottleneck-targeted search
            place = self._policy.place(self.net, self.tau, prev)
            stats = self._policy.last_stats
            if place is None:
                place = prev if prev is not None else \
                    np.zeros(len(self.blocks), dtype=int)
        else:
            place, stats = self.assigner.assign(self.net, self.tau, prev)
            if place is None:
                place = prev if prev is not None else \
                    np.zeros(len(self.blocks), dtype=int)
            # objective filter: keep migrations only if they pay (§III.G).
            # With pipeline_k > 1 the objective is D_pipe(K) + D_mig — a
            # move that lengthens the critical path but relieves the
            # bottleneck resource can now win (k=1 is total_delay
            # bit-for-bit).
            place = revert_unpaying_migrations(prev, place, self.blocks,
                                               self.cost, self.net, self.tau,
                                               k=k,
                                               min_gain=self.cfg.min_gain)
        n_slots = self.net.n_devices
        new_perms = placement_to_perms(place, self.blocks, n_slots,
                                       self.cfg.heads_per_slot,
                                       self.cfg.group_size)
        pairs = [] if self.perms is None else \
            migration_pairs_layers(self.perms, new_perms,
                                   self.cfg.heads_per_slot)
        new_eperms = None
        epairs: List[tuple] = []
        if self.has_experts:
            new_eperms = placement_to_expert_perms(
                place, self.blocks, n_slots, self.experts_per_slot,
                self.cost.expert_replicas)
            if self.expert_perms is not None:
                epairs = migration_pairs_layers(self.expert_perms, new_eperms,
                                                self.experts_per_slot)
        d_mig = migration_delay(prev, place, self.blocks, self.cost,
                                self.net, self.tau)
        plan = {"tau": self.tau, "place": place,
                "perms": new_perms, "prev_perms": self.perms,
                "perm": new_perms[0],
                "prev_perm": None if self.perms is None else self.perms[0],
                "migrations": pairs,
                "expert_perms": new_eperms,
                "prev_expert_perms": self.expert_perms,
                "expert_migrations": epairs,
                "d_mig_est": d_mig,
                "d_pipe_est": pipelined_inference_delay(
                    place, self.blocks, self.cost, self.net, self.tau, k=k),
                "arrival_rate": arrival_rate,
                "queue_depth": queue_depth,
                "infeasible": stats.infeasible}
        self.place, self.perms = place, new_perms
        if new_eperms is not None:
            self.expert_perms = new_eperms
        self.history.append({"tau": self.tau, "n_migrations": len(pairs),
                             "n_expert_migrations": len(epairs),
                             "d_mig_est": d_mig,
                             "arrival_rate": arrival_rate,
                             "queue_depth": queue_depth,
                             "infeasible": stats.infeasible})
        return plan

    # ------------------------------------------------------------- churn
    def handle_failure(self, device: int,
                       tau: Optional[int] = None) -> dict:
        """Death event → evacuation plan: mark ``device`` failed and
        immediately re-place.  The resulting plan's migrations move every
        block off the dead device (the assigner cannot place there), and
        the §III.G payback filter is structurally bypassed for them —
        ``revert_unpaying_migrations`` never reverts a block onto an
        inactive device — so the evacuation is mandatory, not priced.
        Surviving blocks keep their hysteresis stickiness, minimizing
        collateral migrations."""
        self.net.fail(device)
        plan = self.step_interval(tau=tau)
        if np.any(np.asarray(plan["place"]) == device):
            # the infeasible fallback kept blocks on the dead device —
            # survivors cannot hold the model; fail loudly, not silently
            raise RuntimeError(
                f"evacuation infeasible: surviving devices cannot hold "
                f"device {device}'s blocks (n_active={self.net.n_active})")
        plan["evacuation"] = True
        plan["failed_device"] = int(device)
        self.history[-1]["evacuation"] = True
        self.history[-1]["failed_device"] = int(device)
        return plan

    def handle_rejoin(self, device: int,
                      tau: Optional[int] = None) -> dict:
        """A failed device comes back (fresh, no resident state) →
        expansion plan.  Unlike evacuation, expansion is optional: the
        controller only migrates onto the rejoined device when the move
        pays under the normal §III.G filter."""
        self.net.rejoin(device)
        plan = self.step_interval(tau=tau)
        plan["expansion"] = True
        plan["rejoined_device"] = int(device)
        self.history[-1]["expansion"] = True
        self.history[-1]["rejoined_device"] = int(device)
        return plan

    # ---------------------------------------------------------------- act
    def apply_to_cache(self, cache_k, cache_v, plan, head_axis: int = 3,
                       layer_axis: int = 0):
        """Execute the migration plan on a layer-stacked head-expanded KV
        cache: per-layer gathers by the *relative* permutations (new layout
        in terms of current positions), which lower to collective-permute
        between slots.  The cache's ``layer_axis`` must cover the
        controller's ``n_layers`` (a single-layer plan broadcasts over it)."""
        prev_perms = plan.get("prev_perms")
        if prev_perms is None or not plan["migrations"]:
            return cache_k, cache_v
        rel = relative_perms(prev_perms, plan["perms"])
        gs = self.cfg.group_size
        if rel.shape[0] == 1:  # single-layer plan: same perm for all layers
            return apply_head_perm(cache_k, cache_v, rel[0], head_axis,
                                   group_size=gs)
        return apply_layer_head_perms(cache_k, cache_v, rel,
                                      layer_axis=layer_axis,
                                      head_axis=head_axis, group_size=gs)
