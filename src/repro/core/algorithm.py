"""Algorithm 1 — Resource-Aware LLM block assignment at interval τ (paper §IV).

Faithful to the pseudocode:
  1-3  reset counters, start T_max timer, gather {M_j, C_j, R_jk}
  4    sort blocks by descending demand (memory; compute tie-break)
  5-22 per block: score all devices, take argmin; tentative assign; if the
       device's *aggregate* memory/compute over-runs, undo and call
       ResolveResourceOverload; count migrations against U = |B|·|V|
  23-29 global constraint check; BacktrackForResourceViolations
  30   return the assignment (or INFEASIBLE)

Compute feasibility of a device at τ means: summed block processing time
fits the interval deadline (C_j(τ)·deadline FLOPs) — see scoring.py for why
the deadline normalization is needed.

Beyond the pseudocode we also implement the objective-aware tie-break the
text requires ("minimize D_T + D_mig"): when several devices score within
``tie_tol`` of the best, prefer the one with the lowest marginal
(migration + inference) delay contribution.  Disable with
``objective_tiebreak=False`` for the ablation (tests cover both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.blocks import Block, CostModel, graph_of
from repro.core.delay import total_delay
from repro.core.network import DeviceNetwork
from repro.core.scoring import score

INFEASIBLE = None


@dataclasses.dataclass
class AlgoStats:
    migrations: int = 0
    backtracks: int = 0
    elapsed: float = 0.0
    infeasible: bool = False
    score_evals: int = 0


class ResourceAwareAssigner:
    """The paper's myopic per-interval assignment policy."""

    def __init__(self, blocks: Sequence[Block], cost: CostModel,
                 *, deadline: float = 5.0, t_max: float = 10.0,
                 objective_tiebreak: bool = True, tie_tol: float = 0.15,
                 hysteresis: float = 0.9):
        self.blocks = list(blocks)
        self.cost = cost
        self.deadline = deadline
        self.t_max = t_max
        self.objective_tiebreak = objective_tiebreak
        self.tie_tol = tie_tol
        # "at most one migration per head per interval to avoid back-and-forth
        # overhead" (§III.D(a)): a block only leaves its device for a >=
        # (1-hysteresis) score improvement — the anti-thrash discount.
        self.hysteresis = hysteresis

    # ------------------------------------------------------------------ API
    def assign(self, net: DeviceNetwork, tau: int,
               prev: Optional[np.ndarray] = None
               ) -> tuple[Optional[np.ndarray], AlgoStats]:
        stats = AlgoStats()
        t0 = time.monotonic()
        B, V = len(self.blocks), net.n_devices
        U = B * V
        mem = self.cost.memory_vector(self.blocks, tau)
        comp = self.cost.compute_vector(self.blocks, tau)

        # line 4: descending by memory demand (compute tie-break)
        order = sorted(range(B), key=lambda i: (-mem[i], -comp[i]))

        place = np.full(B, -1, dtype=int)
        mem_used = np.zeros(V)
        comp_used = np.zeros(V)

        def assigned_ok(j) -> bool:
            return (net.is_active(j) and
                    mem_used[j] <= net.mem_avail[j] and
                    comp_used[j] <= net.compute_avail[j] * self.deadline)

        def do_place(i, j):
            place[i] = j
            mem_used[j] += mem[i]
            comp_used[j] += comp[i]

        def undo_place(i):
            j = place[i]
            if j >= 0:
                mem_used[j] -= mem[i]
                comp_used[j] -= comp[i]
                place[i] = -1

        def device_order(i: int) -> tuple[List[int], np.ndarray]:
            """Returns (candidate order, raw load-aware scores).  The same
            load-aware scores drive both the sort and the caller's
            feasibility check — one scoring convention (hysteresis and the
            objective tie-break only perturb the *order*, never the raw
            scores the feasibility test reads)."""
            bl = self.blocks[i]
            # Load-aware scores: free memory and queued compute on j are
            # subtracted/added (Algorithm 1 line 10's aggregate check, folded
            # into the score so the argmin spreads load instead of stacking
            # everything on the roomiest device).  Counterpart devices for
            # the comm factor come from the controller's best current
            # knowledge: this round's tentative placement overlaid on prev
            # (-1 = still unknown), so even the first interval sees the
            # links its already-placed proj/ffn/neighbor-layer blocks use.
            view = place if prev is None else np.where(place >= 0, place, prev)
            raw = np.array([
                score(bl, j, self.blocks, view, self.cost, net, tau,
                      deadline=self.deadline, mem_used=mem_used,
                      compute_used=comp_used) for j in range(V)])
            stats.score_evals += V
            scores = raw.copy()
            if prev is not None:
                scores[prev[i]] *= self.hysteresis  # anti-thrash stickiness
            order = list(np.argsort(scores, kind="stable"))
            if self.objective_tiebreak and prev is not None:
                best = scores[order[0]]
                ties = [j for j in order
                        if scores[j] <= best * (1 + self.tie_tol) + 1e-12][:6]
                if len(ties) > 1:
                    def marginal(j):
                        trial = place.copy()
                        trial[i] = j
                        filled = trial.copy()
                        filled[filled < 0] = prev[filled < 0] if prev is not None else 0
                        return total_delay(prev, filled, self.blocks,
                                           self.cost, net, tau)
                    ties.sort(key=marginal)
                    rest = [j for j in order if j not in ties]
                    order = ties + rest
            return order, raw

        # lines 5-22 -----------------------------------------------------
        for i in order:
            if time.monotonic() - t0 > self.t_max:
                return self._fail(stats, t0)
            cand, cand_scores = device_order(i)
            placed = False
            for j in cand:
                if cand_scores[j] > 1.0:
                    # Infeasible under the SAME load-aware convention the
                    # candidate list is sorted by.  Skip rather than break:
                    # hysteresis and the objective tie-break perturb the
                    # order, so a feasible device can follow an infeasible
                    # one (the old load-blind `break` here silently skipped
                    # such devices).
                    continue
                do_place(i, j)
                if assigned_ok(j):
                    placed = True
                    if prev is not None and prev[i] != j:
                        stats.migrations += 1
                        if stats.migrations > U:
                            return self._fail(stats, t0)
                    break
                # line 10-14: revert + try to free capacity
                undo_place(i)
                if self._resolve_overload(i, j, place, mem_used, comp_used,
                                          mem, comp, net, stats, U):
                    do_place(i, j)
                    placed = True
                    break
                stats.migrations += 1
                if stats.migrations > U:
                    return self._fail(stats, t0)
            if not placed:
                # lines 18-21: no device feasible for i alone
                if not self._resolve_overload(i, None, place, mem_used,
                                              comp_used, mem, comp, net,
                                              stats, U):
                    return self._fail(stats, t0)
                # retry on the freshly freed device set (permissive: the
                # desperate path takes any ACTIVE device the aggregate
                # check OKs — liveness is enforced even here, since this
                # path skips the per-block score filter)
                cand, _ = device_order(i)
                for j in cand:
                    if not net.is_active(j):
                        continue
                    do_place(i, j)
                    if assigned_ok(j):
                        placed = True
                        break
                    undo_place(i)
                if not placed:
                    return self._fail(stats, t0)

        # lines 23-29 ------------------------------------------------------
        guard = 0
        while not self._all_ok(place, mem_used, comp_used, net):
            if guard > U or time.monotonic() - t0 > self.t_max:
                return self._fail(stats, t0)
            if not self._backtrack(place, mem_used, comp_used, mem, comp,
                                   net, stats):
                return self._fail(stats, t0)
            stats.backtracks += 1
            guard += 1

        stats.elapsed = time.monotonic() - t0
        return place, stats

    # ------------------------------------------------------------- helpers
    def _fail(self, stats: AlgoStats, t0) -> tuple[None, AlgoStats]:
        stats.infeasible = True
        stats.elapsed = time.monotonic() - t0
        return INFEASIBLE, stats

    def _all_ok(self, place, mem_used, comp_used, net) -> bool:
        if (place < 0).any():
            return False
        return bool(np.all(mem_used <= net.mem_avail + 1e-9) and
                    np.all(comp_used <= net.compute_avail * self.deadline
                           + 1e-9))

    def _resolve_overload(self, i: int, target: Optional[int], place,
                          mem_used, comp_used, mem, comp, net,
                          stats: AlgoStats, U: int) -> bool:
        """ResolveResourceOverload (§IV.B1): migrate already-placed blocks
        away from the overloaded device (smallest sufficient set, smallest
        blocks first) onto devices with headroom."""
        need_mem = mem[i]
        need_comp = comp[i]
        devices = [target] if target is not None else \
            list(np.argsort(mem_used))  # try least-loaded device first
        for j in devices:
            if j is None or not net.is_active(j):
                continue
            movable = [k for k in range(len(place)) if place[k] == j and k != i]
            movable.sort(key=lambda k: mem[k])
            moved: List[tuple[int, int]] = []
            for k in movable:
                if (mem_used[j] + need_mem <= net.mem_avail[j] and
                        comp_used[j] + need_comp
                        <= net.compute_avail[j] * self.deadline):
                    break
                dest = self._find_room(k, j, place, mem_used, comp_used,
                                       mem, comp, net)
                if dest is None:
                    continue
                place[k] = dest
                mem_used[j] -= mem[k]
                comp_used[j] -= comp[k]
                mem_used[dest] += mem[k]
                comp_used[dest] += comp[k]
                moved.append((k, j))
                stats.migrations += 1
                if stats.migrations > U:
                    return False
            if (mem_used[j] + need_mem <= net.mem_avail[j] and
                    comp_used[j] + need_comp
                    <= net.compute_avail[j] * self.deadline):
                return True
            # undo this device's moves and try the next candidate
            for k, src in reversed(moved):
                dest = place[k]
                place[k] = src
                mem_used[dest] -= mem[k]
                comp_used[dest] -= comp[k]
                mem_used[src] += mem[k]
                comp_used[src] += comp[k]
        return False

    def _find_room(self, k: int, avoid: int, place, mem_used, comp_used,
                   mem, comp, net) -> Optional[int]:
        best, best_slack = None, -np.inf
        for j in net.active_ids:
            if j == avoid:
                continue
            if (mem_used[j] + mem[k] <= net.mem_avail[j] and
                    comp_used[j] + comp[k]
                    <= net.compute_avail[j] * self.deadline):
                slack = (net.mem_avail[j] - mem_used[j] - mem[k]) \
                    / net.mem_avail[j]
                if slack > best_slack:
                    best, best_slack = j, slack
        return best

    def _backtrack(self, place, mem_used, comp_used, mem, comp, net,
                   stats: AlgoStats) -> bool:
        """BacktrackForResourceViolations (§IV.B2): remove a minimal set of
        blocks from each violated device (largest first) and re-place them."""
        progressed = False
        for j in range(net.n_devices):
            while (mem_used[j] > net.mem_avail[j] + 1e-9 or
                   comp_used[j] > net.compute_avail[j] * self.deadline + 1e-9):
                on_j = [k for k in range(len(place)) if place[k] == j]
                if not on_j:
                    break
                k = max(on_j, key=lambda t: mem[t])
                dest = self._find_room(k, j, place, mem_used, comp_used,
                                       mem, comp, net)
                if dest is None:
                    return False
                place[k] = dest
                mem_used[j] -= mem[k]
                comp_used[j] -= comp[k]
                mem_used[dest] += mem[k]
                comp_used[dest] += comp[k]
                progressed = True
        return progressed


# ---------------------------------------------------------------------------
# Bottleneck-targeted pipeline placement search (beyond Algorithm 1)
# ---------------------------------------------------------------------------
#
# Algorithm 1 minimizes the myopic single-token objective D_T + D_mig; on
# multi-device edge topologies the pipelined steady state is bounded by the
# busiest single RESOURCE instead (delay.resource_busy_times).  The two
# functions below are the search primitives ResourceAwarePolicy's
# ``search="bottleneck"`` mode composes:
#
#  - ``stage_balanced_chain``: an EdgeShard-style layer→device chain seed
#    whose contiguous layer runs are weighted by per-device compute AND the
#    inter-stage link bytes — the layer-disjoint stage structure Algorithm
#    1's per-block argmin never proposes.
#  - ``refine_bottleneck``: local search that relieves the argmax resource
#    with layer-chain moves (a whole layer relocated as one move,
#    preferentially along fast links) interleaved with the per-block
#    best-improvement sweep, accepting a move only when it strictly lowers
#    D_pipe(k) and its migration bytes amortize over ``amortize`` intervals
#    (the myopic one-interval payback is exactly why rescue migrations
#    never applied under fluctuating load).  Exact D_pipe ties break on
#    D_T + D_mig, the paper objective.


def _pipe_value(prev, place, blocks, cost, net, tau, k: int):
    """(D_pipe(k), D_T + D_mig, D_mig) — the lexicographic search key plus
    the migration component the amortization gate prices separately."""
    from repro.core.delay import (inference_delay, migration_delay,
                                  pipeline_bottleneck)
    d_t = inference_delay(place, blocks, cost, net, tau)
    b = min(pipeline_bottleneck(place, blocks, cost, net, tau), d_t)
    d_pipe = (d_t + (k - 1) * b) / k
    d_mig = migration_delay(prev, place, blocks, cost, net, tau)
    return float(d_pipe), float(d_t + d_mig), float(d_mig)


def stage_balanced_chain(blocks: Sequence[Block], cost: CostModel,
                         net: DeviceNetwork, tau: int, *,
                         pipeline_k: int = 2,
                         rebalance_passes: int = 16) -> Optional[np.ndarray]:
    """Stage-balanced layer→device chain: every block of a contiguous
    layer run on one device, runs sized so no stage's (compute + incoming
    inter-stage transfer) time sticks out.

    Device order is a greedy fast-link path (from every start, keep the
    unvisited device with the fastest link from the current chain end);
    layer shares start proportional to compute_avail and a boundary-layer
    rebalance then walks single layers off the max-time stage.  Candidate
    chains are scored by (D_pipe(pipeline_k), D_T); only memory-feasible
    chains are returned, ``None`` when no start yields one (tiny-memory
    devices)."""
    from repro.core.delay import memory_feasible
    g = graph_of(blocks)
    L = g.n_layers
    act = [int(j) for j in net.active_ids]  # chains only over live devices
    layer_comp = float(sum(cost.compute(b, tau) for b in g.layer_blocks(0)))
    # expert graphs: per-layer compute varies with the router load, so
    # stage compute is a prefix-sum range, not shares[s] x one layer
    # (dense graphs keep the original scalar arithmetic bit-for-bit)
    has_experts = any(g.experts[l] for l in range(L))
    if has_experts:
        comp_cum = np.concatenate(
            [[0.0], np.cumsum([sum(cost.compute(b, tau)
                                   for b in g.layer_blocks(l))
                               for l in range(L)])])
    boundary = cost.interlayer_bytes(tau)

    def chain_placement(devs: List[int], shares: np.ndarray) -> np.ndarray:
        place = np.empty(len(blocks), dtype=int)
        nxt = 0
        for dev, n in zip(devs, shares):
            for _ in range(int(n)):
                for b in g.layer_blocks(nxt):
                    place[b.index] = dev
                nxt += 1
        return place

    def stage_time(devs, shares, s: int) -> float:
        if has_experts:
            start = int(np.sum(shares[:s]))
            comp = comp_cum[start + int(shares[s])] - comp_cum[start]
            t = comp / net.compute_avail[devs[s]]
        else:
            t = shares[s] * layer_comp / net.compute_avail[devs[s]]
        # incoming edge comes from the nearest PRECEDING stage that still
        # holds layers (a rebalanced-to-zero stage is not on the chain)
        src = net.controller
        for p in range(s - 1, -1, -1):
            if shares[p] > 0:
                src = devs[p]
                break
        if src != devs[s]:
            t += boundary / net.bandwidth[src, devs[s]]
        return t

    best: Optional[tuple] = None
    for start in act:
        order, left = [start], set(act) - {start}
        while left:
            nxt = max(left, key=lambda j: net.bandwidth[order[-1], j])
            order.append(nxt)
            left.remove(nxt)
        n = len(order)
        speeds = net.compute_avail[order]
        shares = np.maximum(0, np.round(L * speeds / speeds.sum())).astype(int)
        while shares.sum() > L:
            shares[int(np.argmax(shares))] -= 1
        while shares.sum() < L:
            shares[int(np.argmax(speeds * (shares > 0)))] += 1
        # walk boundary layers off the worst stage onto a chain neighbor
        for _ in range(rebalance_passes):
            used = [s for s in range(n) if shares[s] > 0]
            times = {s: stage_time(order, shares, s) for s in used}
            worst = max(used, key=lambda s: times[s])
            moved = False
            for nb in (worst - 1, worst + 1):
                if not (0 <= nb < n):
                    continue
                trial = shares.copy()
                trial[worst] -= 1
                trial[nb] += 1
                t_used = [s for s in range(n) if trial[s] > 0]
                t_worst = max(stage_time(order, trial, s) for s in t_used)
                if t_worst < times[worst] - 1e-15:
                    shares, moved = trial, True
                    break
            if not moved:
                break
        chain = [(d, int(n)) for d, n in zip(order, shares) if n > 0]
        place = chain_placement([d for d, _ in chain],
                                np.array([n for _, n in chain]))
        if not memory_feasible(place, blocks, cost, net, tau):
            continue
        key = _pipe_value(None, place, blocks, cost, net, tau, pipeline_k)[:2]
        if best is None or key < best[0]:
            best = (key, place)
    return None if best is None else best[1]


def refine_bottleneck(prev: Optional[np.ndarray], place: np.ndarray,
                      blocks: Sequence[Block], cost: CostModel,
                      net: DeviceNetwork, tau: int, *, k: int,
                      amortize: int = 16, rounds: int = 4) -> np.ndarray:
    """Bottleneck-targeted local search: shrink D_pipe(k) by relieving the
    argmax resource of ``resource_busy_times``.

    Each round reads ``bottleneck_attribution``, then tries (a) layer-chain
    moves — every layer with a block on the bottleneck resource relocated
    whole to each feasible device — interleaved with (b) the per-block
    best-improvement sweep scoped to blocks resident on (or transferring
    over) that resource.  A move is accepted only when it strictly lowers
    D_pipe(k) AND the migration delay it adds pays back within ``amortize``
    intervals (``amortize · gain > added D_mig``) — the amortized version
    of §III.G's filter, without which a straggler's rescue migration never
    pays at λ=1 and the placement stays wedged.  Among equal-D_pipe moves
    the lower D_T + D_mig wins (the paper objective as tie-break).

    Monotone: the returned placement's D_pipe(k) is never worse than
    ``place``'s, so callers keep the rescoring policy's guarantees."""
    from repro.core.delay import bottleneck_attribution, memory_usage
    g = graph_of(blocks)
    act = [int(j) for j in net.active_ids]  # moves only target live devices
    mem = cost.memory_vector(blocks, tau)
    cur = np.asarray(place, dtype=int).copy()
    cur_pipe, cur_tie, cur_mig = _pipe_value(prev, cur, blocks, cost, net,
                                             tau, k)
    use = memory_usage(cur, blocks, cost, net, tau)

    def try_move(idxs: List[int], j: int, best: Optional[tuple]):
        """Evaluate relocating blocks ``idxs`` to device ``j``; returns the
        updated best candidate (pipe, tie, mig, j)."""
        old = cur[idxs].copy()
        need = sum(mem[i] for i in idxs if cur[i] != j)
        if use[j] + need > net.mem_avail[j]:
            return best
        cur[idxs] = j
        pipe, tie, mig = _pipe_value(prev, cur, blocks, cost, net, tau, k)
        cur[idxs] = old
        if pipe >= cur_pipe - 1e-15:
            return best
        if amortize * (cur_pipe - pipe) <= (mig - cur_mig):
            return best          # migration bytes never pay back
        if best is None or (pipe, tie) < (best[0], best[1]):
            return (pipe, tie, mig, j)
        return best

    def commit(idxs: List[int], best: tuple):
        nonlocal cur_pipe, cur_tie, cur_mig
        for i in idxs:
            use[cur[i]] -= mem[i]
            use[best[3]] += mem[i]
        cur[idxs] = best[3]
        cur_pipe, cur_tie, cur_mig = best[:3]

    for _ in range(max(0, rounds)):
        improved = False
        kind, ident, _ = bottleneck_attribution(cur, blocks, cost, net, tau)
        hot_devs = {ident} if kind == "device" else set(ident)
        # (a) layer-chain moves: layers touching the bottleneck resource
        for l in range(g.n_layers):
            idxs = [b.index for b in g.layer_blocks(l)]
            if not any(int(cur[i]) in hot_devs for i in idxs):
                continue
            best = None
            for j in act:
                best = try_move(idxs, j, best)
            if best is not None:
                commit(idxs, best)
                improved = True
        # (b) per-block best-improvement sweep over the (possibly new)
        # bottleneck resource's resident blocks
        kind, ident, _ = bottleneck_attribution(cur, blocks, cost, net, tau)
        hot_devs = {ident} if kind == "device" else set(ident)
        for i in range(len(blocks)):
            if int(cur[i]) not in hot_devs:
                continue
            best = None
            for j in act:
                if j != int(cur[i]):
                    best = try_move([i], j, best)
            if best is not None:
                commit([i], best)
                improved = True
        if not improved:
            break
    return cur
