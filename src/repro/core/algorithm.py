"""Algorithm 1 — Resource-Aware LLM block assignment at interval τ (paper §IV).

Faithful to the pseudocode:
  1-3  reset counters, start T_max timer, gather {M_j, C_j, R_jk}
  4    sort blocks by descending demand (memory; compute tie-break)
  5-22 per block: score all devices, take argmin; tentative assign; if the
       device's *aggregate* memory/compute over-runs, undo and call
       ResolveResourceOverload; count migrations against U = |B|·|V|
  23-29 global constraint check; BacktrackForResourceViolations
  30   return the assignment (or INFEASIBLE)

Compute feasibility of a device at τ means: summed block processing time
fits the interval deadline (C_j(τ)·deadline FLOPs) — see scoring.py for why
the deadline normalization is needed.

Beyond the pseudocode we also implement the objective-aware tie-break the
text requires ("minimize D_T + D_mig"): when several devices score within
``tie_tol`` of the best, prefer the one with the lowest marginal
(migration + inference) delay contribution.  Disable with
``objective_tiebreak=False`` for the ablation (tests cover both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.blocks import Block, CostModel
from repro.core.delay import total_delay
from repro.core.network import DeviceNetwork
from repro.core.scoring import score

INFEASIBLE = None


@dataclasses.dataclass
class AlgoStats:
    migrations: int = 0
    backtracks: int = 0
    elapsed: float = 0.0
    infeasible: bool = False
    score_evals: int = 0


class ResourceAwareAssigner:
    """The paper's myopic per-interval assignment policy."""

    def __init__(self, blocks: Sequence[Block], cost: CostModel,
                 *, deadline: float = 5.0, t_max: float = 10.0,
                 objective_tiebreak: bool = True, tie_tol: float = 0.15,
                 hysteresis: float = 0.9):
        self.blocks = list(blocks)
        self.cost = cost
        self.deadline = deadline
        self.t_max = t_max
        self.objective_tiebreak = objective_tiebreak
        self.tie_tol = tie_tol
        # "at most one migration per head per interval to avoid back-and-forth
        # overhead" (§III.D(a)): a block only leaves its device for a >=
        # (1-hysteresis) score improvement — the anti-thrash discount.
        self.hysteresis = hysteresis

    # ------------------------------------------------------------------ API
    def assign(self, net: DeviceNetwork, tau: int,
               prev: Optional[np.ndarray] = None
               ) -> tuple[Optional[np.ndarray], AlgoStats]:
        stats = AlgoStats()
        t0 = time.monotonic()
        B, V = len(self.blocks), net.n_devices
        U = B * V
        mem = self.cost.memory_vector(self.blocks, tau)
        comp = self.cost.compute_vector(self.blocks, tau)

        # line 4: descending by memory demand (compute tie-break)
        order = sorted(range(B), key=lambda i: (-mem[i], -comp[i]))

        place = np.full(B, -1, dtype=int)
        mem_used = np.zeros(V)
        comp_used = np.zeros(V)

        def assigned_ok(j) -> bool:
            return (mem_used[j] <= net.mem_capacity[j] and
                    comp_used[j] <= net.compute_avail[j] * self.deadline)

        def do_place(i, j):
            place[i] = j
            mem_used[j] += mem[i]
            comp_used[j] += comp[i]

        def undo_place(i):
            j = place[i]
            if j >= 0:
                mem_used[j] -= mem[i]
                comp_used[j] -= comp[i]
                place[i] = -1

        def device_order(i: int) -> tuple[List[int], np.ndarray]:
            """Returns (candidate order, raw load-aware scores).  The same
            load-aware scores drive both the sort and the caller's
            feasibility check — one scoring convention (hysteresis and the
            objective tie-break only perturb the *order*, never the raw
            scores the feasibility test reads)."""
            bl = self.blocks[i]
            # Load-aware scores: free memory and queued compute on j are
            # subtracted/added (Algorithm 1 line 10's aggregate check, folded
            # into the score so the argmin spreads load instead of stacking
            # everything on the roomiest device).  Counterpart devices for
            # the comm factor come from the controller's best current
            # knowledge: this round's tentative placement overlaid on prev
            # (-1 = still unknown), so even the first interval sees the
            # links its already-placed proj/ffn/neighbor-layer blocks use.
            view = place if prev is None else np.where(place >= 0, place, prev)
            raw = np.array([
                score(bl, j, self.blocks, view, self.cost, net, tau,
                      deadline=self.deadline, mem_used=mem_used,
                      compute_used=comp_used) for j in range(V)])
            stats.score_evals += V
            scores = raw.copy()
            if prev is not None:
                scores[prev[i]] *= self.hysteresis  # anti-thrash stickiness
            order = list(np.argsort(scores, kind="stable"))
            if self.objective_tiebreak and prev is not None:
                best = scores[order[0]]
                ties = [j for j in order
                        if scores[j] <= best * (1 + self.tie_tol) + 1e-12][:6]
                if len(ties) > 1:
                    def marginal(j):
                        trial = place.copy()
                        trial[i] = j
                        filled = trial.copy()
                        filled[filled < 0] = prev[filled < 0] if prev is not None else 0
                        return total_delay(prev, filled, self.blocks,
                                           self.cost, net, tau)
                    ties.sort(key=marginal)
                    rest = [j for j in order if j not in ties]
                    order = ties + rest
            return order, raw

        # lines 5-22 -----------------------------------------------------
        for i in order:
            if time.monotonic() - t0 > self.t_max:
                return self._fail(stats, t0)
            cand, cand_scores = device_order(i)
            placed = False
            for j in cand:
                if cand_scores[j] > 1.0:
                    # Infeasible under the SAME load-aware convention the
                    # candidate list is sorted by.  Skip rather than break:
                    # hysteresis and the objective tie-break perturb the
                    # order, so a feasible device can follow an infeasible
                    # one (the old load-blind `break` here silently skipped
                    # such devices).
                    continue
                do_place(i, j)
                if assigned_ok(j):
                    placed = True
                    if prev is not None and prev[i] != j:
                        stats.migrations += 1
                        if stats.migrations > U:
                            return self._fail(stats, t0)
                    break
                # line 10-14: revert + try to free capacity
                undo_place(i)
                if self._resolve_overload(i, j, place, mem_used, comp_used,
                                          mem, comp, net, stats, U):
                    do_place(i, j)
                    placed = True
                    break
                stats.migrations += 1
                if stats.migrations > U:
                    return self._fail(stats, t0)
            if not placed:
                # lines 18-21: no device feasible for i alone
                if not self._resolve_overload(i, None, place, mem_used,
                                              comp_used, mem, comp, net,
                                              stats, U):
                    return self._fail(stats, t0)
                # retry on the freshly freed device set (permissive: the
                # desperate path takes any device the aggregate check OKs)
                cand, _ = device_order(i)
                for j in cand:
                    do_place(i, j)
                    if assigned_ok(j):
                        placed = True
                        break
                    undo_place(i)
                if not placed:
                    return self._fail(stats, t0)

        # lines 23-29 ------------------------------------------------------
        guard = 0
        while not self._all_ok(place, mem_used, comp_used, net):
            if guard > U or time.monotonic() - t0 > self.t_max:
                return self._fail(stats, t0)
            if not self._backtrack(place, mem_used, comp_used, mem, comp,
                                   net, stats):
                return self._fail(stats, t0)
            stats.backtracks += 1
            guard += 1

        stats.elapsed = time.monotonic() - t0
        return place, stats

    # ------------------------------------------------------------- helpers
    def _fail(self, stats: AlgoStats, t0) -> tuple[None, AlgoStats]:
        stats.infeasible = True
        stats.elapsed = time.monotonic() - t0
        return INFEASIBLE, stats

    def _all_ok(self, place, mem_used, comp_used, net) -> bool:
        if (place < 0).any():
            return False
        return bool(np.all(mem_used <= net.mem_capacity + 1e-9) and
                    np.all(comp_used <= net.compute_avail * self.deadline
                           + 1e-9))

    def _resolve_overload(self, i: int, target: Optional[int], place,
                          mem_used, comp_used, mem, comp, net,
                          stats: AlgoStats, U: int) -> bool:
        """ResolveResourceOverload (§IV.B1): migrate already-placed blocks
        away from the overloaded device (smallest sufficient set, smallest
        blocks first) onto devices with headroom."""
        need_mem = mem[i]
        need_comp = comp[i]
        devices = [target] if target is not None else \
            list(np.argsort(mem_used))  # try least-loaded device first
        for j in devices:
            if j is None:
                continue
            movable = [k for k in range(len(place)) if place[k] == j and k != i]
            movable.sort(key=lambda k: mem[k])
            moved: List[tuple[int, int]] = []
            for k in movable:
                if (mem_used[j] + need_mem <= net.mem_capacity[j] and
                        comp_used[j] + need_comp
                        <= net.compute_avail[j] * self.deadline):
                    break
                dest = self._find_room(k, j, place, mem_used, comp_used,
                                       mem, comp, net)
                if dest is None:
                    continue
                place[k] = dest
                mem_used[j] -= mem[k]
                comp_used[j] -= comp[k]
                mem_used[dest] += mem[k]
                comp_used[dest] += comp[k]
                moved.append((k, j))
                stats.migrations += 1
                if stats.migrations > U:
                    return False
            if (mem_used[j] + need_mem <= net.mem_capacity[j] and
                    comp_used[j] + need_comp
                    <= net.compute_avail[j] * self.deadline):
                return True
            # undo this device's moves and try the next candidate
            for k, src in reversed(moved):
                dest = place[k]
                place[k] = src
                mem_used[dest] -= mem[k]
                comp_used[dest] -= comp[k]
                mem_used[src] += mem[k]
                comp_used[src] += comp[k]
        return False

    def _find_room(self, k: int, avoid: int, place, mem_used, comp_used,
                   mem, comp, net) -> Optional[int]:
        V = net.n_devices
        best, best_slack = None, -np.inf
        for j in range(V):
            if j == avoid:
                continue
            if (mem_used[j] + mem[k] <= net.mem_capacity[j] and
                    comp_used[j] + comp[k]
                    <= net.compute_avail[j] * self.deadline):
                slack = (net.mem_capacity[j] - mem_used[j] - mem[k]) \
                    / net.mem_capacity[j]
                if slack > best_slack:
                    best, best_slack = j, slack
        return best

    def _backtrack(self, place, mem_used, comp_used, mem, comp, net,
                   stats: AlgoStats) -> bool:
        """BacktrackForResourceViolations (§IV.B2): remove a minimal set of
        blocks from each violated device (largest first) and re-place them."""
        progressed = False
        for j in range(net.n_devices):
            while (mem_used[j] > net.mem_capacity[j] + 1e-9 or
                   comp_used[j] > net.compute_avail[j] * self.deadline + 1e-9):
                on_j = [k for k in range(len(place)) if place[k] == j]
                if not on_j:
                    break
                k = max(on_j, key=lambda t: mem[t])
                dest = self._find_room(k, j, place, mem_used, comp_used,
                                       mem, comp, net)
                if dest is None:
                    return False
                place[k] = dest
                mem_used[j] -= mem[k]
                comp_used[j] -= comp[k]
                mem_used[dest] += mem[k]
                comp_used[dest] += comp[k]
                progressed = True
        return progressed
