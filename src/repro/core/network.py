"""Edge-device network model (paper §III.B).

Devices are heterogeneous: memory M_j(τ), max compute W_j, available compute
C_j(τ) <= W_j (background load), link bandwidths R_{j,k}(τ).  Sampled from
log-normal distributions per §V.B(b): M in [2,8] GB, C in [5,50] GFLOPS,
links in [1,10] Gbps, full connectivity.  Background tasks are injected as a
multiplicative availability process (mean-reverting), matching the paper's
"inject background tasks to emulate fluctuating compute load".

The same class doubles as the TPU-bridge capacity model: ``from_mesh``
builds a homogeneous device set from mesh topology (hop-scaled ICI), with
straggler injection for the fault-tolerance runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

GB = 1024 ** 3
GFLOPS = 1e9
GBPS = 1e9 / 8  # bytes/sec per Gbps


@dataclasses.dataclass
class DeviceNetwork:
    """State of |V| devices and the |V|x|V| link matrix at interval tau."""

    mem_capacity: np.ndarray      # (V,) bytes, M_j(tau)
    compute_max: np.ndarray       # (V,) FLOP/s, W_j
    compute_avail: np.ndarray     # (V,) FLOP/s, C_j(tau)
    bandwidth: np.ndarray         # (V,V) bytes/s, R_{j,k}(tau)
    controller: int = 0           # node issuing inference requests
    rng: Optional[np.random.Generator] = None
    # background-load process parameters (§V.B "inject background tasks"):
    # tasks arrive per-device with prob `bg_arrival` per interval, consume a
    # U[0.3,0.7] fraction of W_j, and depart with prob 1/bg_duration —
    # persistent load shifts, plus small white-noise jitter.
    bg_volatility: float = 0.05
    bg_floor: float = 0.1
    bg_arrival: float = 0.01
    bg_duration: float = 150.0
    _bg_tasks: Optional[list] = None  # per-device list of load fractions
    _pinned_load: Optional["np.ndarray"] = None  # injected stragglers
    # Elastic churn state.  `active` is the liveness mask: a failed device
    # stays in the arrays (indices — and therefore permutation geometry —
    # never shift) but exposes zero availability and may not receive
    # blocks.  `_mem_avail` backs the *instantaneous* memory availability
    # M_j(τ) the controller observes, distinct from the hardware
    # `mem_capacity` (which observation must never overwrite — the
    # Controller.observe() conflation bug); until the first observation it
    # tracks capacity, so capacity edits keep constraining placement.
    active: Optional[np.ndarray] = None       # (V,) bool, liveness mask
    _mem_avail: Optional[np.ndarray] = None   # (V,) bytes, observed M_j(tau)

    def __post_init__(self):
        if self.active is None:
            self.active = np.ones(self.n_devices, dtype=bool)

    @property
    def mem_avail(self) -> np.ndarray:
        """(V,) observed memory availability; capacity until observed."""
        return self.mem_capacity if self._mem_avail is None \
            else self._mem_avail

    @mem_avail.setter
    def mem_avail(self, value):
        self._mem_avail = None if value is None \
            else np.asarray(value, float).copy()

    @property
    def n_devices(self) -> int:
        return len(self.mem_capacity)

    # ------------------------------------------------------------ liveness
    @property
    def n_active(self) -> int:
        return int(np.count_nonzero(self.active))

    @property
    def active_ids(self) -> np.ndarray:
        """Indices of live devices — the only legal placement targets."""
        return np.flatnonzero(self.active)

    def is_active(self, j: int) -> bool:
        return bool(self.active[j])

    def mem_usable(self) -> np.ndarray:
        """(V,) usable memory: observed availability, zero when inactive."""
        return np.where(self.active, self.mem_avail, 0.0)

    def fail(self, j: int):
        """Device j dies: zero availability, excluded from placement.
        Indices are preserved so existing placements/permutations remain
        addressable — the controller must evacuate, not reindex."""
        self.active[j] = False
        self.compute_avail[j] = 0.0
        if self._mem_avail is not None:
            self._mem_avail[j] = 0.0  # mem_usable() masks either way
        if self._pinned_load is not None:
            self._pinned_load[j] = 0.0

    def rejoin(self, j: int):
        """A previously failed device comes back, fresh (full capacity,
        no resident state).  The engine-facing join: physical slot
        geometry is fixed at construction, so an engine expansion is a
        slot re-activating — ``join`` (new index) is for the planning
        layers, whose placements are not tied to a cache shape."""
        self.active[j] = True
        if self._mem_avail is not None:
            self._mem_avail[j] = self.mem_capacity[j]
        self.compute_avail[j] = self.compute_max[j]
        if self._pinned_load is not None:
            self._pinned_load[j] = 0.0

    def slow(self, j: int, factor: float):
        """Device j becomes `factor`x slower (persistent pinned load)."""
        if factor < 1.0:
            raise ValueError(f"slowdown factor must be >= 1, got {factor}")
        if not self.active[j]:
            return
        self.inject_straggler(j, factor)

    def join(self, mem: float, compute: float,
             bw_row: "np.ndarray") -> int:
        """A new device joins with `mem` bytes, `compute` FLOP/s, and
        symmetric link bandwidths `bw_row` (len V) to the existing
        devices.  Returns the new device's index."""
        bw_row = np.asarray(bw_row, float)
        if bw_row.shape != (self.n_devices,):
            raise ValueError(
                f"bw_row must have shape ({self.n_devices},), "
                f"got {bw_row.shape}")
        if mem <= 0 or compute <= 0 or np.any(bw_row <= 0):
            raise ValueError("joining device needs positive mem/compute/bw")
        v = self.n_devices
        self.mem_capacity = np.append(self.mem_capacity, float(mem))
        if self._mem_avail is not None:
            self._mem_avail = np.append(self._mem_avail, float(mem))
        self.compute_max = np.append(self.compute_max, float(compute))
        self.compute_avail = np.append(self.compute_avail, float(compute))
        self.active = np.append(self.active, True)
        bw = np.full((v + 1, v + 1), np.inf)
        bw[:v, :v] = self.bandwidth
        bw[v, :v] = bw_row
        bw[:v, v] = bw_row
        self.bandwidth = bw
        if self._bg_tasks is not None:
            self._bg_tasks.append([])
        if self._pinned_load is not None:
            self._pinned_load = np.append(self._pinned_load, 0.0)
        return v

    # ------------------------------------------------------------- sampling
    @classmethod
    def sample(cls, n_devices: int, seed: int = 0, *,
               mem_range=(2 * GB, 8 * GB),
               compute_range=(5 * GFLOPS, 50 * GFLOPS),
               bw_range=(1 * GBPS, 10 * GBPS),
               controller: int = 0) -> "DeviceNetwork":
        """Log-normal heterogeneity clipped to the paper's ranges (§V.B)."""
        rng = np.random.default_rng(seed)

        def lognormal_in(lo, hi, size):
            mu, sigma = 0.0, 0.5
            raw = rng.lognormal(mu, sigma, size)
            # map quantiles of the lognormal into [lo, hi]
            lo_q, hi_q = np.exp(mu - 2 * sigma), np.exp(mu + 2 * sigma)
            x = np.clip((raw - lo_q) / (hi_q - lo_q), 0.0, 1.0)
            return lo + x * (hi - lo)

        mem = lognormal_in(*mem_range, n_devices)
        wmax = lognormal_in(*compute_range, n_devices)
        bw = lognormal_in(*bw_range, (n_devices, n_devices))
        bw = (bw + bw.T) / 2.0
        np.fill_diagonal(bw, np.inf)  # same-device transfer is free
        return cls(mem_capacity=mem, compute_max=wmax,
                   compute_avail=wmax.copy(), bandwidth=bw,
                   controller=controller, rng=rng)

    @classmethod
    def from_mesh(cls, shape, *, hbm_bytes=16 * GB, peak_flops=197e12,
                  link_bw=50e9, seed: int = 0) -> "DeviceNetwork":
        """Homogeneous TPU slice: devices = mesh slots; R_{j,k} = ICI bw
        scaled by inverse hop count on the torus (DESIGN.md §2)."""
        coords = np.array(np.unravel_index(np.arange(np.prod(shape)), shape)).T
        n = len(coords)
        hops = np.zeros((n, n))
        for d, size in enumerate(shape):
            diff = np.abs(coords[:, None, d] - coords[None, :, d])
            hops += np.minimum(diff, size - diff)  # torus wrap
        hops = np.maximum(hops, 1)
        bw = link_bw / hops
        np.fill_diagonal(bw, np.inf)
        return cls(mem_capacity=np.full(n, float(hbm_bytes)),
                   compute_max=np.full(n, float(peak_flops)),
                   compute_avail=np.full(n, float(peak_flops)),
                   bandwidth=bw, controller=0,
                   rng=np.random.default_rng(seed))

    # ----------------------------------------------------------- dynamics
    def step_background_load(self):
        """Persistent background-task arrivals/departures + jitter."""
        assert self.rng is not None
        if self._bg_tasks is None:
            self._bg_tasks = [[] for _ in range(self.n_devices)]
        for j in self.active_ids:
            # departures
            self._bg_tasks[j] = [f for f in self._bg_tasks[j]
                                 if self.rng.random() > 1.0 / self.bg_duration]
            # arrivals
            if self.rng.random() < self.bg_arrival:
                self._bg_tasks[j].append(float(self.rng.uniform(0.3, 0.7)))
            load = sum(self._bg_tasks[j])
            pinned = 0.0 if self._pinned_load is None else self._pinned_load[j]
            jitter = self.rng.normal(0.0, self.bg_volatility)
            # injected stragglers may sink below the organic-load floor
            floor = self.bg_floor * (0.1 if pinned > 0 else 1.0)
            frac = np.clip(1.0 - load - pinned + jitter, floor, 1.0)
            self.compute_avail[j] = self.compute_max[j] * frac

    def inject_straggler(self, device: int, slowdown: float):
        """Fault-tolerance hook: device becomes `slowdown`x slower,
        persistently (survives step_background_load as pinned load)."""
        if not self.active[device]:
            return
        if self._pinned_load is None:
            self._pinned_load = np.zeros(self.n_devices)
        self._pinned_load[device] = 1.0 - 1.0 / slowdown
        self.compute_avail[device] = self.compute_max[device] / slowdown

    def restore(self, device: int):
        if not self.active[device]:
            return
        if self._pinned_load is not None:
            self._pinned_load[device] = 0.0
        self.compute_avail[device] = self.compute_max[device]

    def copy(self) -> "DeviceNetwork":
        return DeviceNetwork(self.mem_capacity.copy(), self.compute_max.copy(),
                             self.compute_avail.copy(), self.bandwidth.copy(),
                             self.controller, self.rng,
                             self.bg_volatility, self.bg_floor,
                             self.bg_arrival, self.bg_duration,
                             None if self._bg_tasks is None else
                             [list(t) for t in self._bg_tasks],
                             None if self._pinned_load is None else
                             self._pinned_load.copy(),
                             self.active.copy(),
                             None if self._mem_avail is None else
                             self._mem_avail.copy())
