"""The paper's contribution: attention-head-level partitioning + myopic
resource-aware migration for low-latency edge LLM inference."""
from repro.core.algorithm import (  # noqa: F401
    AlgoStats,
    ResourceAwareAssigner,
    refine_bottleneck,
    stage_balanced_chain,
)
from repro.core.baselines import (  # noqa: F401
    ALL_POLICIES,
    BottleneckAwarePolicy,
    ColumnCoPartitionPolicy,
    DynamicLayerPolicy,
    EdgeShardPolicy,
    GalaxyPolicy,
    GreedyPolicy,
    Policy,
    ResourceAwarePolicy,
    RoundRobinPolicy,
    StaticPolicy,
)
from repro.core.blocks import (  # noqa: F401
    Block,
    BlockGraph,
    CostModel,
    FFN,
    HEAD,
    PROJ,
    blocks_per_layer,
    graph_of,
    make_blocks,
    replicate_placement,
    stage_partition,
)
from repro.core.delay import (  # noqa: F401
    bottleneck_attribution,
    inference_delay,
    memory_feasible,
    memory_usage,
    migration_delay,
    pipeline_bottleneck,
    pipelined_inference_delay,
    pipelined_total_delay,
    resource_busy_times,
    total_delay,
)
from repro.core.network import DeviceNetwork, GB, GBPS, GFLOPS  # noqa: F401
from repro.core.scoring import comm_factor, score, score_matrix  # noqa: F401
from repro.core.simulator import SimResult, compare_policies, simulate  # noqa: F401
from repro.core.solver import exact_horizon, exact_myopic  # noqa: F401
