"""Placement bridge: Algorithm 1's block→device assignment realized as a
TPU sharding (DESIGN.md §2/§4).

SPMD cannot place arbitrary programs per chip, but an *arbitrary head→slot
assignment* is exactly a permutation of the head axis composed with the
regular head-sharded PartitionSpec: slot s of the "model" axis holds heads
``perm[s*Hp/tp : (s+1)*Hp/tp]``.  Placement changes are permutation-index
changes; applying the delta permutation to the KV cache *is* the paper's
migration, and lowers to the collective-permute traffic Eq. (2) prices.

Also here: path-based parameter PartitionSpecs (the params side of the
head-level TP layout models express via activation constraints).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.blocks import Block, HEAD, expert_slot, graph_of


# ---------------------------------------------------------------------------
# Algorithm-1 placement -> head permutation (one per layer)
# ---------------------------------------------------------------------------


def placement_to_perm(place: np.ndarray, blocks: Sequence[Block],
                      n_slots: int, heads_per_slot: int,
                      group_size: int = 1) -> np.ndarray:
    """Maps a block placement (head i -> device j) onto a head permutation.

    Head-blocks assigned to slot j occupy that slot's contiguous positions.
    If the assignment is unbalanced (more heads on a device than
    heads_per_slot — legal at the edge, not under SPMD) the overflow spills
    to the next slots round-robin; the spill count is reported so the
    controller can price it as extra migrations.

    ``group_size`` > 1 (GQA: ``group_size = Hp // KvE`` query heads share
    each KV head) makes the permutation *group-consistent*: whole KV groups
    are the migration unit — every block of ``group_size`` output positions
    holds one complete group in canonical within-group order, so the
    induced KV permutation (``kv_group_perms``) is well defined and grouped
    caches/weights physically move with their query heads.  A group whose
    heads Algorithm 1 scattered over several devices is snapped to the
    majority device (ties to the lowest device id); when ``group_size``
    exceeds ``heads_per_slot`` a group spans adjacent slots — the
    co-holding models KV replication across those slots.
    """
    if group_size > 1:
        return _placement_to_group_perm(place, blocks, n_slots,
                                        heads_per_slot, group_size)
    head_ids = [b.head_id for b in blocks if b.kind == HEAD]
    n_heads = len(head_ids)
    assert n_slots * heads_per_slot >= n_heads
    buckets: List[List[int]] = [[] for _ in range(n_slots)]
    spilled: List[int] = []
    for b in blocks:
        if b.kind != HEAD:
            continue
        j = int(place[b.index]) % n_slots
        if len(buckets[j]) < heads_per_slot:
            buckets[j].append(b.head_id)
        else:
            spilled.append(b.head_id)
    for h in spilled:
        j = int(np.argmin([len(bk) for bk in buckets]))
        buckets[j].append(h)
    perm = []
    for bk in buckets:
        perm.extend(bk)
        perm.extend([-1] * (heads_per_slot - len(bk)))  # padded positions
    # fill padding with the unused (padded) head ids
    unused = [h for h in range(n_slots * heads_per_slot) if h not in perm]
    out = np.array(perm)
    out[out == -1] = unused
    return out


def _placement_to_group_perm(place: np.ndarray, blocks: Sequence[Block],
                             n_slots: int, heads_per_slot: int,
                             group_size: int) -> np.ndarray:
    """Group-granular variant of ``placement_to_perm`` (see its docstring):
    assigns whole KV groups to slots by majority vote over their heads'
    placements and emits the head permutation that moves groups as units.

    Permutation positions keep their slot meaning (slot s = positions
    [s·hps, (s+1)·hps)): each block of ``group_size`` contiguous positions
    has a *primary slot* and every group takes the free block nearest its
    majority slot — so a group physically relocating between slots changes
    the permutation (and therefore produces migration pairs) even when the
    slot *order* of the groups is unchanged."""
    positions = n_slots * heads_per_slot
    if positions % group_size:
        raise ValueError(f"{positions} head positions not divisible by "
                         f"KV group size {group_size}")
    heads = [b for b in blocks if b.kind == HEAD]
    n_heads = len(heads)
    if n_heads % group_size:
        raise ValueError(f"{n_heads} heads not divisible by KV group "
                         f"size {group_size}")
    assert positions >= n_heads
    dev_of = {b.head_id: int(place[b.index]) % n_slots for b in heads}
    n_groups = n_heads // group_size
    total_blocks = positions // group_size
    # position-block p covers perm positions [p·G, (p+1)·G); its primary
    # slot is the one holding the block's first position
    primary = [(p * group_size) // heads_per_slot
               for p in range(total_blocks)]
    free = list(range(total_blocks))
    order = np.full(total_blocks, -1, dtype=int)
    for g in range(n_groups):
        votes = np.bincount([dev_of[g * group_size + i]
                             for i in range(group_size)],
                            minlength=n_slots)
        pref = int(np.argmax(votes))       # majority, ties -> lowest slot
        p = min(free, key=lambda p: (abs(primary[p] - pref), p))
        order[p] = g
        free.remove(p)
    # padded group ids (beyond the real heads) fill the remaining blocks
    for g, p in zip(range(n_groups, total_blocks), free):
        order[p] = g
    out = np.empty(positions, dtype=int)
    for p, g in enumerate(order):
        out[p * group_size:(p + 1) * group_size] = \
            g * group_size + np.arange(group_size)
    return out


def placement_to_perms(place: np.ndarray, blocks: Sequence[Block],
                       n_slots: int, heads_per_slot: int,
                       group_size: int = 1) -> np.ndarray:
    """Per-layer head permutations for a (possibly multi-layer) block
    graph: row l is ``placement_to_perm`` applied to layer l's blocks.
    Shape (n_layers, n_slots·heads_per_slot); a single-layer list yields
    one row, identical to ``placement_to_perm``.  ``group_size`` > 1 makes
    every row group-consistent (GQA migrates whole KV groups)."""
    g = graph_of(blocks)
    return np.stack([placement_to_perm(place, g.layer_blocks(l),
                                       n_slots, heads_per_slot, group_size)
                     for l in range(g.n_layers)])


def placement_to_expert_perms(place: np.ndarray, blocks: Sequence[Block],
                              n_slots: int, experts_per_slot: int,
                              expert_replicas: int = 1) -> np.ndarray:
    """Per-layer *expert-slot* permutations — the expert analog of
    ``placement_to_perms``.  Row l maps permutation position p (mesh slot
    ``p // experts_per_slot``) to the physical expert-row id
    (``blocks.expert_slot``: expert_id·R + replica) Algorithm 1 placed
    there; overflow beyond a slot's capacity spills round-robin exactly
    like head spill.  Shape (n_layers, n_slots·experts_per_slot) — for the
    permutation to be physically applicable to the weight stacks,
    ``n_slots·experts_per_slot`` must equal the number of physical expert
    rows (asserted)."""
    g = graph_of(blocks)
    positions = n_slots * experts_per_slot
    rows = []
    for l in range(g.n_layers):
        ebs = g.experts[l]
        assert positions == len(ebs), (positions, len(ebs))
        buckets: List[List[int]] = [[] for _ in range(n_slots)]
        spilled: List[int] = []
        for b in ebs:
            j = int(place[b.index]) % n_slots
            sid = expert_slot(b, expert_replicas)
            if len(buckets[j]) < experts_per_slot:
                buckets[j].append(sid)
            else:
                spilled.append(sid)
        for sid in spilled:
            j = int(np.argmin([len(bk) for bk in buckets]))
            buckets[j].append(sid)
        perm: List[int] = []
        for bk in buckets:
            perm.extend(bk)
        rows.append(np.array(perm))
    return np.stack(rows)


def permute_model_experts_layers(params, perms):
    """Physically relocate MoE expert rows: row l of ``perms`` reorders
    layer l's physical expert axis of ``w_gate/w_up/w_down`` AND the
    ``owner``/``share`` maps that travel with the rows — the expert twin of
    ``permute_model_heads_layers``.  The combine scatters physical rows
    back into logical-expert order (models.moe), so the model function is
    bit-identical — only which mesh slot holds which expert row changes.
    Requires owner/share to be present (the serving engine installs
    identity maps at init for MoE archs)."""
    idx = jnp.asarray(perms)

    def take(w, axis):
        axis = axis % w.ndim
        shape = [1] * w.ndim
        shape[0] = idx.shape[0]
        shape[axis] = idx.shape[1]
        return jnp.take_along_axis(w, idx.reshape(shape), axis=axis)

    def visit(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k == "moe" and isinstance(v, dict):
                    if "owner" not in v:
                        raise ValueError(
                            "expert migration needs owner/share maps "
                            "(install moe.expert_identity first)")
                    m = dict(v)
                    for n in ("w_gate", "w_up", "w_down"):
                        m[n] = take(v[n], -3)
                    for n in ("owner", "share"):
                        m[n] = take(v[n], -1)
                    out[k] = m
                else:
                    out[k] = visit(v)
            return out
        return tree

    return visit(params)


def kv_group_perms(perms: np.ndarray, group_size: int) -> np.ndarray:
    """The KV-head permutation stack induced by group-consistent query-head
    permutations: kv position p of row l holds old kv head
    ``perms[l, p·G] // G``.  Shape (L, H/G).  Raises ``ValueError`` when a
    block of ``group_size`` positions mixes heads from different KV groups
    — the permutation then has no grouped-cache realization and applying it
    would silently corrupt GQA attention."""
    perms = np.atleast_2d(np.asarray(perms))
    if group_size <= 1:
        return perms
    L, H = perms.shape
    if H % group_size:
        raise ValueError(f"perm width {H} not divisible by group size "
                         f"{group_size}")
    grouped = perms.reshape(L, H // group_size, group_size) // group_size
    if not (grouped == grouped[:, :, :1]).all():
        raise ValueError("head permutation is not KV-group-consistent: "
                         "a block of positions mixes heads from different "
                         "KV groups (emit perms via placement_to_perms("
                         "group_size=...) for grouped-KV archs)")
    out = grouped[:, :, 0]
    for l in range(L):
        if sorted(out[l].tolist()) != list(range(H // group_size)):
            raise ValueError(f"induced KV permutation of layer {l} is not "
                             f"a permutation: {out[l]}")
    return out


def expand_kv_perms(kv_perms: np.ndarray, rep: int) -> np.ndarray:
    """Expanded-KV (replicated) row permutation induced by a KV-head
    permutation: caches of ``rep``-replicated archs (``HeadDims.rep`` > 1,
    tp > n_kv_heads) store ``KvE = Kp·rep`` rows where expanded row
    ``o·rep + r`` is replica r of KV head o.  Replicas are exact copies,
    so a KV-head permutation lifts to the expanded layout by moving each
    head's whole replica block: new expanded row ``o·rep + r`` holds old
    expanded row ``kv_perms[.., o]·rep + r``.  Shape (L, Kp) -> (L, KvE);
    ``rep=1`` is the identity lift."""
    kv = np.atleast_2d(np.asarray(kv_perms))
    if rep <= 1:
        return kv
    out = kv[:, :, None] * rep + np.arange(rep)
    return out.reshape(kv.shape[0], -1)


def placement_to_head_slices(place: np.ndarray, blocks: Sequence[Block],
                             n_slots: int, layer: Optional[int] = None):
    """Per-(layer, slot) resident head rows of a BlockGraph placement — the
    gather maps the resident-slice decode kernel consumes
    (``kernels.decode_attention.decode_attention_resident``).

    Returns ``[layer][slot] -> np.ndarray`` of sorted logical head ids the
    placement puts on that slot (``layer=l`` selects one layer's list).
    The per-slot arrays are RAGGED — per-layer head counts per device are
    not uniform under the per-layer block graph — and their union over
    slots is exactly layer l's head set: every head's attention runs
    exactly once, on the device that hosts it.  This is the same placement
    the cost model prices and ``placement_to_perms`` snaps onto the SPMD
    mesh, so kernel dispatch, pricing, and migration all read one source
    of truth.  Devices fold onto slots modulo ``n_slots`` — the same
    deliberate device→slot folding every bridge function uses (a network
    larger than the engine's slot count is the normal serve-CLI case);
    keep them in lockstep or the maps stop describing the applied
    permutations."""
    g = graph_of(blocks)
    out = []
    for l in range(g.n_layers):
        buckets: List[List[int]] = [[] for _ in range(n_slots)]
        for b in g.heads[l]:
            buckets[int(place[b.index]) % n_slots].append(b.head_id)
        out.append([np.array(sorted(bk), dtype=np.int32) for bk in buckets])
    return out if layer is None else out[layer]


def head_row_maps(place: np.ndarray, blocks: Sequence[Block], n_slots: int,
                  total_rows: int, perms: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Stacked kernel gather maps for a full-model decode step.

    Row l of the returned ``rows`` (n_layers, total_rows) array lists the
    PHYSICAL q-head rows of layer l in slot-grouped placement order: the
    concatenation over slots of each slot's resident slice
    (``placement_to_head_slices``), padded q-head rows (logical ids ≥ the
    placed head count) appended at the tail.  ``perms`` — the physical
    layout actually applied to weights/caches (position p holds logical
    head ``perms[l, p]``) — maps logical ids to physical positions; omit
    it while the layout is still the identity.  Also returns ``inv``
    (n_layers, total_rows), the scatter map with ``rows[l][inv[l]] ==
    arange``: gathering the kernel's compacted output by ``inv[l]``
    restores physical q order for the wo projection.

    A single-slot dispatch uses one slice of ``placement_to_head_slices``
    directly; this stacked form is the single-host (and per-layer-scan)
    emulation — the union of every slot's resident dispatch."""
    slices = placement_to_head_slices(place, blocks, n_slots)
    n_layers = len(slices)
    rows = np.empty((n_layers, total_rows), dtype=np.int32)
    inv = np.empty_like(rows)
    for l, per_slot in enumerate(slices):
        logical = np.concatenate([s for s in per_slot] or
                                 [np.empty(0, np.int32)])
        n_placed = logical.shape[0]
        if n_placed > total_rows:
            raise ValueError(f"layer {l} places {n_placed} heads but the "
                             f"model has only {total_rows} head rows")
        pad = np.setdiff1d(np.arange(total_rows, dtype=np.int32), logical)
        logical = np.concatenate([logical, pad])
        if perms is not None:
            pstack = np.atleast_2d(np.asarray(perms))
            p = pstack[0] if pstack.shape[0] == 1 else pstack[l]
            if p.shape[0] != total_rows:
                raise ValueError(f"perm width {p.shape[0]} != head rows "
                                 f"{total_rows}")
            inv_perm = np.empty(total_rows, dtype=np.int32)
            inv_perm[np.asarray(p, dtype=int)] = np.arange(total_rows)
            rows[l] = inv_perm[logical]
        else:
            rows[l] = logical
        inv[l] = np.argsort(rows[l])
    return rows, inv


def identity_head_rows(n_layers: int, total_rows: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """The trivial gather maps (physical == logical == dense grid): what a
    kernelized decode runs before any controller plan exists."""
    rows = np.broadcast_to(np.arange(total_rows, dtype=np.int32),
                           (n_layers, total_rows)).copy()
    return rows, rows.copy()


def migration_pairs(old_perm: np.ndarray, new_perm: np.ndarray,
                    heads_per_slot: int) -> List[Tuple[int, int, int]]:
    """(head, src_slot, dst_slot) for every head whose slot changes."""
    slot_of_old = {h: i // heads_per_slot for i, h in enumerate(old_perm)}
    out = []
    for i, h in enumerate(new_perm):
        src, dst = slot_of_old[int(h)], i // heads_per_slot
        if src != dst:
            out.append((int(h), src, dst))
    return out


def migration_pairs_layers(old_perms: np.ndarray, new_perms: np.ndarray,
                           heads_per_slot: int
                           ) -> List[Tuple[int, int, int, int]]:
    """(layer, head, src_slot, dst_slot) over all layers' permutations."""
    out: List[Tuple[int, int, int, int]] = []
    for l, (op, np_) in enumerate(zip(old_perms, new_perms)):
        out.extend((l, h, s, d)
                   for h, s, d in migration_pairs(op, np_, heads_per_slot))
    return out


def relative_perms(prev_perms: np.ndarray, new_perms: np.ndarray
                   ) -> np.ndarray:
    """Per-layer relative permutations: row l maps the *current* physical
    layout (prev_perms[l]) onto the new one — ``take``-ing a cache/weight
    head axis by row l realizes layer l's migration.  Accepts (L, H) stacks
    or single (H,) permutations (returned as shape (1, H))."""
    prev_perms = np.atleast_2d(np.asarray(prev_perms))
    new_perms = np.atleast_2d(np.asarray(new_perms))
    if prev_perms.shape[0] == 1 and new_perms.shape[0] > 1:
        # one physical layout shared by all layers
        prev_perms = np.broadcast_to(prev_perms, new_perms.shape)
    if prev_perms.shape != new_perms.shape:
        raise ValueError(f"perm stacks disagree: {prev_perms.shape} vs "
                         f"{new_perms.shape}")
    out = np.empty_like(new_perms)
    for l, (pp, np_) in enumerate(zip(prev_perms, new_perms)):
        old_pos = {int(h): i for i, h in enumerate(pp)}
        out[l] = [old_pos[int(h)] for h in np_]
    return out


def apply_head_perm(cache_k, cache_v, perm, head_axis: int = 3,
                    group_size: int = 1, rep: int = 1):
    """Reorders the expanded-KV head axis of a stacked cache
    ((L, B, T, KvE, dh) by default).  Under a head-sharded mesh this gather
    lowers to collective-permute / all-to-all between slots — the physical
    migration.  ``group_size`` > 1: ``perm`` is a (group-consistent)
    query-head permutation and the cache head axis holds one KV head per
    group — the induced KV permutation is applied instead.  ``rep`` > 1
    (replicated-KV archs): the induced Kp-row permutation is lifted to the
    KvE replicated rows via ``expand_kv_perms``."""
    if group_size > 1:
        perm = expand_kv_perms(kv_group_perms(perm, group_size), rep)[0]
    idx = jnp.asarray(perm)
    return (jnp.take(cache_k, idx, axis=head_axis),
            jnp.take(cache_v, idx, axis=head_axis))


def apply_layer_head_perms(cache_k, cache_v, perms, *, layer_axis: int = 0,
                           head_axis: int = 3, group_size: int = 1,
                           rep: int = 1):
    """Per-layer reorder of a stacked cache ((L, B, T, KvE, dh) by default):
    row l of ``perms`` permutes layer l's head axis.  Under a head-sharded
    mesh each row lowers to collective-permute / all-to-all between slots —
    the physical per-layer migration.  ``group_size`` > 1: rows are
    (group-consistent) query-head permutations while the cache head axis is
    KV heads (one per group) — rows are mapped through ``kv_group_perms``
    so grouped caches physically move with their query heads instead of
    being silently skipped.  ``rep`` > 1 additionally lifts the induced
    Kp-row permutations onto the KvE replicated cache rows
    (``expand_kv_perms``) — the replica-aware migration that makes
    ``HeadDims.rep > 1`` engines migratable.

    ``perms`` may carry MULTIPLE leading index dims — e.g. (G, 4, H) for a
    VLM supergroup cache stack (G, 4, B, T, KvE, dh) — occupying the cache
    axes starting at ``layer_axis``; each leading cell then gets its own
    head permutation (per-supergroup-row VLM migration, no all-layers-equal
    restriction)."""
    if group_size > 1:
        shp = np.shape(perms)
        flat = expand_kv_perms(
            kv_group_perms(np.asarray(perms).reshape(-1, shp[-1]),
                           group_size), rep)
        perms = flat.reshape(tuple(shp[:-1]) + (flat.shape[-1],))
    idx = jnp.asarray(perms)

    def take(c):
        shape = [1] * c.ndim
        la = layer_axis % c.ndim
        for a in range(idx.ndim - 1):
            shape[la + a] = idx.shape[a]
        shape[head_axis % c.ndim] = idx.shape[-1]
        return jnp.take_along_axis(c, idx.reshape(shape),
                                   axis=head_axis % c.ndim)
    return take(cache_k), take(cache_v)


def migration_bytes(pairs: Sequence[Tuple[int, int, int]],
                    bytes_per_head: float) -> float:
    return float(len(pairs) * bytes_per_head)


def permute_model_heads(params, perm, *, has_bias: bool = False,
                        group_size: int = 1):
    """Physically relocate attention heads: permute the head axis of the
    per-head weight slices so head i lands on the mesh slot Algorithm 1
    chose.  Attention is permutation-equivariant over heads (wo sums over
    them), so the model *function* is bit-identical — only the placement
    (which chip holds which head) changes.

    ``group_size`` > 1 (GQA, ``Hp // KvE``): ``perm`` must be
    group-consistent; q-side weights (wq/wo/bq) move by the query-head
    permutation, kv-side weights (wk/wv/bk/bv) by the induced KV-group
    permutation — whole groups migrate, so the q→kv association is
    preserved and the function stays invariant.

    params: full model params (stacked layers supported via negative axes).
    """
    idx = jnp.asarray(perm)
    kv_idx = idx if group_size <= 1 else \
        jnp.asarray(kv_group_perms(perm, group_size)[0])

    def visit(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k == "attn" and isinstance(v, dict):
                    a = dict(v)
                    a["wq"] = jnp.take(v["wq"], idx, axis=-2)
                    a["wk"] = jnp.take(v["wk"], kv_idx, axis=-2)
                    a["wv"] = jnp.take(v["wv"], kv_idx, axis=-2)
                    a["wo"] = jnp.take(v["wo"], idx, axis=-3)
                    if "bq" in v:
                        a["bq"] = jnp.take(v["bq"], idx, axis=-2)
                    for b in ("bk", "bv"):
                        if b in v:
                            a[b] = jnp.take(v[b], kv_idx, axis=-2)
                    out[k] = a
                else:
                    out[k] = visit(v)
            return out
        return tree

    return visit(params)


def permute_model_heads_layers(params, perms, *, has_bias: bool = False,
                               group_size: int = 1):
    """Per-layer physical head relocation: row l of ``perms`` permutes the
    head axis of layer l's attention weights.  Requires layer-stacked attn
    params with the layer axis leading (the dense transformer's
    ``params["layers"]`` layout).  Attention is permutation-equivariant
    over heads *within each layer* (wo sums over them), so any combination
    of per-layer permutations leaves the model function bit-identical —
    only which chip holds which (layer, head) changes.

    ``group_size`` > 1 (GQA): rows must be group-consistent; wq/wo/bq move
    by the query-head rows, wk/wv/bk/bv by the induced per-layer KV-group
    permutations (``kv_group_perms``) — the grouped-KV migration that used
    to be silently skipped.

    ``perms`` may carry multiple leading index dims — (G, 4, H) for the
    VLM's supergroup-stacked self-attn params — matching the params' own
    leading stack axes (per-layer VLM migration, see
    ``apply_layer_head_perms``).
    """
    idx = jnp.asarray(perms)
    if group_size <= 1:
        kv = idx
    else:
        shp = np.shape(perms)
        kvf = kv_group_perms(np.asarray(perms).reshape(-1, shp[-1]),
                             group_size)
        kv = jnp.asarray(kvf.reshape(tuple(shp[:-1]) + (kvf.shape[-1],)))

    def take(w, axis, rows):
        axis = axis % w.ndim
        shape = [1] * w.ndim
        for a in range(rows.ndim - 1):
            shape[a] = rows.shape[a]
        shape[axis] = rows.shape[-1]
        return jnp.take_along_axis(w, rows.reshape(shape), axis=axis)

    def visit(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k == "attn" and isinstance(v, dict):
                    a = dict(v)
                    a["wq"] = take(v["wq"], -2, idx)
                    a["wk"] = take(v["wk"], -2, kv)
                    a["wv"] = take(v["wv"], -2, kv)
                    a["wo"] = take(v["wo"], -3, idx)
                    if "bq" in v:
                        a["bq"] = take(v["bq"], -2, idx)
                    for b in ("bk", "bv"):
                        if b in v:
                            a[b] = take(v[b], -2, kv)
                    out[k] = a
                else:
                    out[k] = visit(v)
            return out
        return tree

    return visit(params)


def stage_slot_partition(place, blocks: Sequence[Block],
                         n_slots: int) -> List[tuple]:
    """Mesh-slot view of ``BlockGraph.stage_partition``: contiguous layer
    stages whose *slot* sets (device % n_slots) are adjacent-disjoint.
    ``len()`` bounds the micro-batch depth K a serving engine can usefully
    keep in flight on this placement — stage s+1's slots are free to start
    the next token while stage s finishes the previous one."""
    g = graph_of(blocks)
    slot_place = np.asarray(place, dtype=int) % n_slots
    return [(frozenset(devs), layer_ids)
            for devs, layer_ids in g.stage_partition(slot_place)]


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs (path-based rules)
# ---------------------------------------------------------------------------

def _path_names(path) -> List[str]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return names


def param_spec(path_names: List[str], ndim: int, cfg: ModelConfig,
               tp: int, *, fsdp: bool, pod_ep: bool,
               layout: str = "tp", shape: tuple = (),
               n_devices: int = 256) -> P:
    """Trailing-dims spec for one parameter, padded with leading Nones
    (stacked-layer axes are never sharded)."""
    name = path_names[-1] if path_names else ""
    quant_part = None
    if name in ("q8", "sc") and len(path_names) >= 2:
        quant_part = name
        name = path_names[-2]          # rules keyed by the weight name
    in_attn = "attn" in path_names
    if layout == "zero3":
        # every axis is DP: shard each param over the flattened device set
        # on its largest evenly-divisible dim (gathered per layer on use);
        # small/indivisible leaves stay replicated.
        if quant_part == "sc" or ndim <= 1 or not shape:
            return P(*([None] * ndim))
        axes: list = [None] * ndim
        cands = sorted(range(ndim), key=lambda d: -shape[d])
        for d in cands:
            if shape[d] % n_devices == 0:
                axes[d] = ("data", "model")
                return P(*axes)
        for d in cands:  # partial sharding over one axis still helps
            if shape[d] % tp == 0:
                axes[d] = "model"
                return P(*axes)
        return P(*([None] * ndim))
    F = "data" if fsdp else None
    kv_ok = cfg.n_kv_heads == 0 or cfg.n_kv_heads % tp == 0 \
        or cfg.n_heads % tp != 0  # padded archs keep Kp divisible too
    KV = "model" if (cfg.expanded_kv_heads(tp) and
                     cfg.padded_heads(tp) and kv_ok) else None
    EP = "pod" if pod_ep else None

    trailing: Optional[tuple] = None
    if name == "tok_embed":
        trailing = ("model", F)
    elif name == "lm_head":
        trailing = (F, "model")
    elif in_attn and name == "wq":
        trailing = (F, "model", None)
    elif in_attn and name in ("wk", "wv"):
        trailing = (F, KV, None)
    elif in_attn and name == "wo":
        trailing = ("model", None, F)
    elif in_attn and name == "bq":
        trailing = ("model", None)
    elif in_attn and name in ("bk", "bv"):
        trailing = (KV, None)
    elif name in ("w_gate", "w_up"):
        # dense (D,F) or moe (E,D,F)
        trailing = (EP, F, "model") if ndim >= 3 else (F, "model")
    elif name == "w_down":
        trailing = (EP, "model", F) if ndim >= 3 else ("model", F)
    elif name == "b_up":
        trailing = ("model",)
    elif name == "router":
        trailing = (None, None)
    # rwkv6 time/channel mix
    elif name in ("wr", "wk", "wv", "wg", "wcr"):
        trailing = (F, "model")
    elif name == "wo" and not in_attn:
        trailing = ("model", F)
    elif name == "wck":
        trailing = (F, "model")
    elif name == "wcv":
        trailing = ("model", F)
    elif name == "lora_A":
        trailing = (F, None)
    elif name == "u":
        trailing = ("model", None)
    # mamba2
    elif name == "w_in":
        trailing = (F, "model")
    elif name == "w_out":
        trailing = ("model", F)

    if trailing is None:
        trailing = ()
    if quant_part == "sc":
        # per-last-axis scale vector: inherits the weight's last-dim spec
        trailing = trailing[-1:] if trailing else ()
    trailing = tuple(trailing[-ndim:]) if ndim < len(trailing) else trailing
    lead = (None,) * (ndim - len(trailing))
    return P(*(lead + tuple(trailing)))


def param_shardings(params_tree, cfg: ModelConfig, mesh: Mesh, *,
                    fsdp: bool = False, layout: str = "tp"):
    """NamedSharding pytree for params (or any mirrored state like AdamW
    moments)."""
    tp = mesh.shape["model"]
    pod_ep = cfg.is_moe and "pod" in mesh.axis_names
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        ndim = len(leaf.shape)
        specs.append(NamedSharding(
            mesh, param_spec(names, ndim, cfg, tp, fsdp=fsdp,
                             pod_ep=pod_ep, layout=layout,
                             shape=tuple(leaf.shape),
                             n_devices=mesh.size)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_shardings(batch_tree, mesh: Mesh, layout: str = "tp"):
    """Token batches: batch dim over (pod?, data) — or the whole mesh for
    zero3; everything else replicated."""
    if layout == "zero3":
        data_axes = tuple(mesh.axis_names)
    else:
        data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def shard(leaf):
        spec = [data_axes] + [None] * (leaf.ndim - 1) if leaf.ndim >= 1 else []
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(shard, batch_tree)


def decode_state_shardings(state_tree, cfg: ModelConfig, mesh: Mesh, *,
                           seq_over_data: bool = False):
    """KV caches: (lead..., B, T, KvE, dh) -> batch over data, heads over
    model (co-location invariant). long_500k (batch=1): cache seq over data.
    SSM states: (lead..., B, H, dh, ns|dh) -> heads over model."""
    data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    # long_500k runs at batch=1: nothing can shard over data except the
    # cache sequence dim; SSM/shift states keep batch unsharded.
    batch_axes = None if seq_over_data else data_axes
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    out = []
    for path, leaf in flat:
        names = _path_names(path)
        nm = names[-1]
        ndim = leaf.ndim
        if nm in ("k", "v") and "img_kv" in names:
            # static image KV: (G, B, I, KvE, dh)
            spec = [None] * (ndim - 4) + [batch_axes, None, "model", None]
        elif nm in ("k", "v") and ndim >= 4:
            # (lead..., B, T, KvE, dh); long_500k shards T over data instead
            if seq_over_data:
                spec = [None] * (ndim - 4) + [None, "data", "model", None]
            else:
                spec = [None] * (ndim - 4) + [batch_axes, None, "model", None]
        elif nm in ("k_sc", "v_sc") and ndim >= 3:    # (lead,B,T,KvE)
            if seq_over_data:
                spec = [None] * (ndim - 3) + [None, "data", "model"]
            else:
                spec = [None] * (ndim - 3) + [batch_axes, None, "model"]
        elif nm == "wkv" and ndim >= 4:               # rwkv (lead,B,H,dh,dh)
            spec = [None] * (ndim - 4) + [batch_axes, "model", None, None]
        elif nm == "ssm" and ndim >= 4:               # mamba (lead,B,nh,dh,ns)
            spec = [None] * (ndim - 4) + [batch_axes, "model", None, None]
        elif nm == "conv" and ndim >= 3:              # (lead,B,cw-1,C)
            spec = [None] * (ndim - 3) + [batch_axes, None, "model"]
        elif nm in ("shift_t", "shift_c") and ndim >= 2:
            spec = [None] * (ndim - 2) + [batch_axes, None]
        elif nm == "pos":
            spec = []
        elif ndim >= 1:
            spec = [batch_axes] + [None] * (ndim - 1)
        else:
            spec = []
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
