"""Block set B = H ∪ {ffn|experts, proj} and the Table-I resource model
(paper §III.C), extended with per-expert MoE blocks.

Memory m_i(τ) and compute b_i(τ) per block at interval τ, with λ=1 token per
interval so the sequence length is L_τ = L0 + τ.

Table I (d = D/h, b = bytes/param):
  head i : mem 3·L·d·b + 3·D·d·b            compute 3·L·D·d + L²·d
  cache  : mem τ·D·b (attached to its head)  —
  proj   : mem L·D·b                         compute L·D²
  ffn    : mem 4·L·D·b                       compute 8·L·D²

``cache_mode``:
  "paper"   — per-head cache τ·D·b exactly as printed (§III.C says m_i(τ)
              includes "the K/V cache of attention head i plus its params").
  "precise" — per-head K+V is 2·τ·d·b (beyond-paper studies; DESIGN.md §7).

``compute_mode``:
  "paper"       — full-sequence reprocessing per interval, as in Table I.
  "incremental" — KV-cache-reusing decode: one new token costs
                  3·D·d + 2·L·d MACs per head (the TPU bridge uses this).

``layer_mode`` — how a multi-layer decoder is lifted from Table I:
  "columns" — a *block* is the per-head column across all layers (the
              original aggregate lift): every per-block quantity scales by
              ``n_layers`` and the block list stays single-layer.  Head i of
              every layer is forced onto one device; inter-layer transfers
              are invisible.
  "graph"   — a true per-layer block graph: ``make_blocks(h, n_layers)``
              emits head(l,i)/proj(l)/ffn(l) blocks, each priced at its
              single-layer Table-I cost, with explicit inter-layer edges
              ffn(l) → head(l+1,·) carrying the full activation L·D·b
              (``interlayer_bytes``).  The paper notes the algorithm "can be
              applied independently to each layer" — this mode makes that
              literal: each layer's heads place independently.

``n_layers=1`` makes the two modes coincide with Table I exactly as printed.

Communication volumes (Eq. 3/4): W_{i→proj} = L·d·b, W_{proj→ffn} = L·D·b
("paper"); incremental mode sends only the new token's activations
(d·b and D·b).  The inter-layer edge carries the same volume as
W_{proj→ffn} — the full hidden state entering the next layer.

Expert blocks (``n_experts >= 2``) replace the monolithic ffn of a layer
with one block per (expert, replica slot):

  expert(l,e,r): mem  3·D·F·b   (weights only — no KV/sequence term, so
                                 Eq. 7 migration moves exactly the
                                 w_gate/w_up/w_down rows)
                 compute  load(l,e,r) · [today's ffn cost]
                 comm  in  load-fraction-scaled W_{proj→ffn} (router
                       fan-out), out load-fraction-scaled inter-layer
                       activation (combine)

``expert_loads`` is the router's observed token share per physical slot
(Σ over a layer's slots = 1; default: 1/E on each expert's first replica
slot, 0 on the rest).  With uniform loads and co-located experts the
per-device load fraction is exactly 1.0 (binary-exact for power-of-two
E), so the delay model prices the expert graph bit-for-bit equal to the
dense ffn graph — memory deliberately differs (expert weights 3·D·F·b
vs the paper's activation-coupled 4·L·D·b ffn term).

Replication is a first-class move: ``expert_replicas=r`` pre-provisions
r placeable slots per expert; activating a replica reassigns load across
the expert's slots (gates renormalise — Σ load per layer stays 1) and
the replica's weight bytes are paid on whatever device hosts it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

FFN = "ffn"
PROJ = "proj"
HEAD = "head"
EXPERT = "expert"

LAYER_MODES = ("columns", "graph")


@dataclasses.dataclass(frozen=True)
class Block:
    index: int           # position in the (layer-major) block list
    kind: str            # head | ffn | proj | expert
    head_id: int = -1    # for kind == head
    layer: int = 0       # decoder layer this block belongs to
    expert_id: int = -1  # logical expert (kind == expert)
    replica: int = 0     # replica slot of that expert (kind == expert)

    @property
    def name(self) -> str:
        if self.kind == HEAD:
            base = f"head{self.head_id}"
        elif self.kind == EXPERT:
            base = f"expert{self.expert_id}" if self.replica == 0 \
                else f"expert{self.expert_id}r{self.replica}"
        else:
            base = self.kind
        return base if self.layer == 0 else f"l{self.layer}:{base}"


def blocks_per_layer(n_heads: int, n_experts: int = 0,
                     expert_replicas: int = 1) -> int:
    ffn_like = n_experts * expert_replicas if n_experts >= 2 else 1
    return n_heads + 1 + ffn_like


def make_blocks(n_heads: int, n_layers: int = 1, n_experts: int = 0,
                expert_replicas: int = 1) -> List[Block]:
    """Layer-major block list: layer l holds heads 0..h-1, proj(l), then
    either ffn(l) or — when ``n_experts >= 2`` — expert(l,e,r) blocks in
    (expert, replica) order.

    ``n_layers=1`` (the default) reproduces the original single-layer list
    bit-for-bit, and ``n_experts`` of 0 or 1 emits the identical dense
    list — a 1-expert MoE *is* an ffn as far as placement is concerned.
    """
    blocks: List[Block] = []
    per = blocks_per_layer(n_heads, n_experts, expert_replicas)
    for l in range(n_layers):
        base = l * per
        for i in range(n_heads):
            blocks.append(Block(base + i, HEAD, head_id=i, layer=l))
        blocks.append(Block(base + n_heads, PROJ, layer=l))
        if n_experts >= 2:
            p = base + n_heads + 1
            for e in range(n_experts):
                for r in range(expert_replicas):
                    blocks.append(Block(p, EXPERT, layer=l,
                                        expert_id=e, replica=r))
                    p += 1
        else:
            blocks.append(Block(base + n_heads + 1, FFN, layer=l))
    return blocks


def expert_slot(block: Block, expert_replicas: int) -> int:
    """Physical expert-slot index of an expert block within its layer
    ((expert, replica)-major — the row order ``expert_loads`` and the
    engine's expert permutations use)."""
    return block.expert_id * expert_replicas + block.replica


class BlockGraph:
    """Layer-indexed view of a block list plus the inter-layer edges.

    ``edges`` lists the explicit ffn(l) → head(l+1, i) activation edges the
    per-layer delay/scoring models price (volume:
    ``CostModel.interlayer_bytes``).
    """

    def __init__(self, blocks: Sequence[Block]):
        # keep the caller's list object when possible: graph_of's cache is
        # keyed by id(list) and guarded by `g.blocks is blocks`
        if not isinstance(blocks, list):
            blocks = list(blocks)
        self.blocks = blocks
        self.n_layers = max(b.layer for b in blocks) + 1
        self.heads: List[List[Block]] = [[] for _ in range(self.n_layers)]
        self.experts: List[List[Block]] = [[] for _ in range(self.n_layers)]
        self.proj: List[Block] = [None] * self.n_layers  # type: ignore
        self.ffn: List[Block] = [None] * self.n_layers   # type: ignore
        for b in blocks:
            if b.kind == HEAD:
                self.heads[b.layer].append(b)
            elif b.kind == EXPERT:
                self.experts[b.layer].append(b)
            elif b.kind == PROJ:
                if self.proj[b.layer] is not None:
                    raise ValueError(f"duplicate proj in layer {b.layer}")
                self.proj[b.layer] = b
            else:
                if self.ffn[b.layer] is not None:
                    raise ValueError(f"duplicate ffn in layer {b.layer}")
                self.ffn[b.layer] = b
        for l in range(self.n_layers):
            if not self.heads[l] or self.proj[l] is None:
                raise ValueError(f"layer {l} is missing blocks")
            if (self.ffn[l] is None) == (not self.experts[l]):
                raise ValueError(f"layer {l} needs exactly one of ffn / "
                                 f"expert blocks")

    def layer_blocks(self, l: int) -> List[Block]:
        if self.ffn[l] is not None:
            return self.heads[l] + [self.proj[l], self.ffn[l]]
        return self.heads[l] + [self.proj[l]] + self.experts[l]

    def out_blocks(self, l: int) -> List[Block]:
        """The blocks producing layer l's output hidden state: the dense
        ffn, or the expert set whose weighted combine feeds layer l+1."""
        return [self.ffn[l]] if self.ffn[l] is not None else self.experts[l]

    @property
    def edges(self):
        """Inter-layer activation edges (ffn|expert(l), head(l+1, i))."""
        return [(src, h)
                for l in range(self.n_layers - 1)
                for src in self.out_blocks(l)
                for h in self.heads[l + 1]]

    def stage_partition(self, place) -> List[tuple]:
        """Pipeline-stage view of a placement: maximal contiguous layer
        runs greedily merged while their device sets intersect.  Adjacent
        stages use disjoint device sets, so tokens in consecutive stages
        can execute concurrently — the in-flight structure
        ``pipelined_inference_delay`` prices (non-adjacent stages may still
        share devices; the delay model's resource busy times, not this
        view, bound the achievable overlap).

        Returns ``[(frozenset devices, (layer, ...)), ...]`` in layer
        order; ``len()`` is the natural micro-batch depth of the placement.
        """
        stages: List[tuple] = []
        for l in range(self.n_layers):
            devs = {int(place[b.index]) for b in self.layer_blocks(l)}
            if stages and (stages[-1][0] & devs):
                stages[-1][0].update(devs)
                stages[-1][1].append(l)
            else:
                stages.append((set(devs), [l]))
        return [(frozenset(d), tuple(ls)) for d, ls in stages]


def stage_partition(place, blocks: Sequence[Block]) -> List[tuple]:
    """Module-level convenience: ``graph_of(blocks).stage_partition``."""
    return graph_of(blocks).stage_partition(place)


# Keyed by (id, len) with a strong reference to the list held in the value:
# while an entry lives, its list's id cannot be reused, so the key cannot
# alias a different list.  Bounded: cleared wholesale if it ever grows past
# a size no realistic process reaches organically.
_GRAPH_CACHE: dict = {}


def graph_of(blocks: Sequence[Block]) -> BlockGraph:
    blocks = blocks if isinstance(blocks, list) else list(blocks)
    key = (id(blocks), len(blocks))
    g = _GRAPH_CACHE.get(key)
    if g is not None and g.blocks is blocks:
        return g
    g = BlockGraph(blocks)
    if len(_GRAPH_CACHE) > 256:
        _GRAPH_CACHE.clear()
    _GRAPH_CACHE[key] = g
    return g


def replicate_placement(col_place, blocks: Sequence[Block]):
    """Lift a single-layer (column) placement onto a per-layer block list:
    head(l,i) ← col_place[head i], proj(l)/ffn(l) ← col_place[proj/ffn].

    This is exactly what ``layer_mode="columns"`` forces implicitly — the
    explicit form lets column co-partitioning be evaluated (and beaten)
    under the per-layer delay model."""
    import numpy as np
    g = graph_of(blocks)
    col = np.asarray(col_place, dtype=int)
    out = np.empty(len(g.blocks), dtype=int)
    n_heads = len(g.heads[0])
    for l in range(g.n_layers):
        for h in g.heads[l]:
            out[h.index] = col[h.head_id]
        out[g.proj[l].index] = col[n_heads]
        if g.ffn[l] is not None:
            out[g.ffn[l].index] = col[n_heads + 1]
        else:
            # expert layers: a dense column (h+2 slots) broadcasts its ffn
            # slot to every expert; an expert-aware column maps by position
            for j, e in enumerate(g.experts[l]):
                src = n_heads + 1 if len(col) == n_heads + 2 \
                    else n_heads + 1 + j
                out[e.index] = col[src]
    return out


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Table-I resource usage for an ``n_layers``-deep decoder.

    ``layer_mode="columns"`` is the original aggregate lift (§V.B, the
    paper's "GPT-2/LLaMA scale" evaluation): a *block* is the per-head
    column across all layers, so memory/compute/communication volumes all
    scale by ``n_layers`` and the block list stays single-layer
    (EXPERIMENTS.md §Reproduction notes).

    ``layer_mode="graph"`` prices each block at its single-layer Table-I
    cost; the multi-layer structure lives in the block list
    (``make_blocks(h, n_layers)``) and the per-layer delay model instead.

    ``n_layers=1`` makes both modes Table I exactly as printed.
    """

    d_model: int                 # D
    n_heads: int                 # h
    bytes_per_param: int = 2     # b
    L0: int = 64                 # prompt length
    lam: int = 1                 # λ tokens per interval
    n_layers: int = 1
    cache_mode: str = "paper"
    compute_mode: str = "paper"
    flops_per_mac: int = 2       # Table I counts MACs; FLOPs = 2x
    layer_mode: str = "columns"
    # page-granular KV (serving engines with a paged cache): the cache
    # term of a head block is rounded UP to whole pages, so migration/
    # memory pricing matches what the engine actually allocates and
    # moves — live pages, not a dense max_seq reservation.  0 = dense.
    page_size: int = 0
    # --- MoE: per-expert blocks instead of a monolithic ffn ---------------
    # n_experts >= 2 makes make_blocks emit expert(l,e,r) blocks; d_ff is
    # the expert hidden width F (0 -> the dense 4·D) used for the
    # weight-only memory/migration term; expert_loads is the observed
    # router token share per (layer, physical slot) — a tuple of n_layers
    # tuples of length n_experts·expert_replicas summing to 1 per layer
    # (() = uniform: 1/E on each expert's first replica slot).
    n_experts: int = 0
    expert_replicas: int = 1
    d_ff: int = 0
    expert_loads: tuple = ()

    def __post_init__(self):
        if self.layer_mode not in LAYER_MODES:
            raise ValueError(f"layer_mode must be one of {LAYER_MODES}, "
                             f"got {self.layer_mode!r}")
        if self.expert_loads:
            want = self.n_experts * self.expert_replicas
            for row in self.expert_loads:
                if len(row) != want:
                    raise ValueError(
                        f"expert_loads rows must have n_experts·"
                        f"expert_replicas = {want} entries, got {len(row)}")

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def expert_dim(self) -> int:
        """Expert hidden width F (falls back to the dense 4·D)."""
        return self.d_ff if self.d_ff > 0 else 4 * self.d_model

    @property
    def expert_slots(self) -> int:
        """Physical expert slots per layer (logical experts × replicas)."""
        return self.n_experts * self.expert_replicas

    def expert_load(self, block: Block) -> float:
        """Observed router token share of one expert block's slot.

        Defaults to uniform 1/E on each expert's first replica slot (so a
        freshly built model with no observations prices exactly like the
        dense ffn split E ways); replica slots beyond the first carry no
        load until the controller activates them."""
        if not self.expert_loads:
            return 1.0 / self.n_experts if block.replica == 0 else 0.0
        row = self.expert_loads[min(block.layer,
                                    len(self.expert_loads) - 1)]
        return float(row[expert_slot(block, self.expert_replicas)])

    def with_expert_loads(self, loads) -> "CostModel":
        """A copy of this model pricing the given per-(layer, slot) router
        loads (any nested sequence; stored as hashable tuples)."""
        t = tuple(tuple(float(x) for x in row) for row in loads)
        return dataclasses.replace(self, expert_loads=t)

    @property
    def _scale(self) -> int:
        """Per-block multiplier: columns aggregate all layers into each
        block; graph blocks are single-layer."""
        return 1 if self.layer_mode == "graph" else self.n_layers

    def seq_len(self, tau: int) -> int:
        return self.L0 + self.lam * tau

    def make_blocks(self) -> List[Block]:
        """The block list this cost model prices: per-layer in graph mode,
        the single-layer column list otherwise."""
        return make_blocks(self.n_heads,
                           self.n_layers if self.layer_mode == "graph" else 1,
                           self.n_experts, self.expert_replicas)

    # ----------------------------------------------------------- memory
    def memory(self, block: Block, tau: int) -> float:
        D, d, b = self.d_model, self.d_head, self.bytes_per_param
        L = self.seq_len(tau)
        if block.kind == HEAD:
            base = 3 * L * d * b + 3 * D * d * b
            t = tau if self.page_size <= 0 \
                else -(-tau // self.page_size) * self.page_size
            if self.cache_mode == "paper":
                cache = t * D * b
            else:
                cache = 2 * t * d * b
            return float(self._scale * (base + cache))
        if block.kind == PROJ:
            return float(self._scale * L * D * b)
        if block.kind == EXPERT:
            # weight-only (w_gate/w_up/w_down rows): no KV/sequence term,
            # so Eq. 7 migration of an expert moves exactly its 3·D·F·b
            return float(self._scale * 3 * D * self.expert_dim * b)
        return float(self._scale * 4 * L * D * b)  # ffn

    # ----------------------------------------------------------- compute
    def compute(self, block: Block, tau: int) -> float:
        D, d = self.d_model, self.d_head
        L = self.seq_len(tau)
        f = self.flops_per_mac * self._scale
        if self.compute_mode == "paper":
            if block.kind == HEAD:
                return float(f * (3 * L * D * d + L * L * d))
            if block.kind == PROJ:
                return float(f * (L * D * D))
            if block.kind == EXPERT:
                # today's ffn cost × the slot's observed token share:
                # uniform load splits the dense 8·L·D² exactly E ways
                return float(f * (8 * L * D * D) * self.expert_load(block))
            return float(f * (8 * L * D * D))
        # incremental: only the λ new tokens are processed
        n = self.lam
        if block.kind == HEAD:
            return float(f * n * (3 * D * d + 2 * L * d))
        if block.kind == PROJ:
            return float(f * n * (D * D))
        if block.kind == EXPERT:
            return float(f * n * (8 * D * D) * self.expert_load(block))
        return float(f * n * (8 * D * D))

    # ------------------------------------------------------ communication
    def head_to_proj_bytes(self, tau: int) -> float:
        d, b = self.d_head, self.bytes_per_param
        L = self.seq_len(tau)
        n = L if self.compute_mode == "paper" else self.lam
        return float(self._scale * n * d * b)

    def proj_to_ffn_bytes(self, tau: int) -> float:
        D, b = self.d_model, self.bytes_per_param
        L = self.seq_len(tau)
        n = L if self.compute_mode == "paper" else self.lam
        return float(self._scale * n * D * b)

    def interlayer_bytes(self, tau: int) -> float:
        """Volume of one ffn(l) → head(l+1,·) edge: the full hidden state
        entering the next layer (L·D·b; incremental mode sends only the λ
        new tokens' activations).  Per-edge — never scaled by n_layers."""
        D, b = self.d_model, self.bytes_per_param
        n = self.seq_len(tau) if self.compute_mode == "paper" else self.lam
        return float(n * D * b)

    def input_bytes(self, tau: int) -> float:
        """Controller -> head-device token embeddings."""
        D, b = self.d_model, self.bytes_per_param
        n = self.seq_len(tau) if self.compute_mode == "paper" else self.lam
        return float(n * D * b)

    # vectors over the standard block list -----------------------------------
    def memory_vector(self, blocks: Sequence[Block], tau: int):
        import numpy as np
        return np.array([self.memory(bl, tau) for bl in blocks])

    def compute_vector(self, blocks: Sequence[Block], tau: int):
        import numpy as np
        return np.array([self.compute(bl, tau) for bl in blocks])


def uniform_expert_loads(n_layers: int, n_experts: int,
                         expert_replicas: int = 1) -> tuple:
    """The default load tensor made explicit: 1/E on each expert's first
    replica slot, 0 on the rest."""
    row = []
    for _ in range(n_experts):
        row.append(1.0 / n_experts)
        row.extend(0.0 for _ in range(expert_replicas - 1))
    return tuple(tuple(row) for _ in range(n_layers))


def replicate_hot_expert(cost: "CostModel", layer: int = None) -> "CostModel":
    """Hot-expert replication as a cost-model move: split the argmax-load
    slot's token share in half onto an idle replica slot of the same
    expert (gates renormalise across replicas, so Σ load per layer is
    unchanged — 0.5· is exact in binary fp).  Layers with no idle replica
    slot for their hot expert are left as they are; ``layer`` restricts
    the move to one layer.  Returns a new CostModel (no-op if
    ``expert_replicas == 1``)."""
    if cost.n_experts < 2 or cost.expert_replicas < 2:
        return cost
    loads = cost.expert_loads or uniform_expert_loads(
        cost.n_layers, cost.n_experts, cost.expert_replicas)
    R = cost.expert_replicas
    new_rows = []
    for l, row in enumerate(loads):
        row = list(row)
        if layer is None or layer == l:
            hot = max(range(len(row)), key=lambda p: row[p])
            e = hot // R
            idle = [e * R + r for r in range(R)
                    if row[e * R + r] == 0.0]
            if idle:
                half = row[hot] * 0.5
                row[hot] = half
                row[idle[0]] = half
        new_rows.append(tuple(row))
    return cost.with_expert_loads(new_rows)
