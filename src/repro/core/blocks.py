"""Block set B = H ∪ {ffn, proj} and the Table-I resource model (paper §III.C).

Memory m_i(τ) and compute b_i(τ) per block at interval τ, with λ=1 token per
interval so the sequence length is L_τ = L0 + τ.

Table I (d = D/h, b = bytes/param):
  head i : mem 3·L·d·b + 3·D·d·b            compute 3·L·D·d + L²·d
  cache  : mem τ·D·b (attached to its head)  —
  proj   : mem L·D·b                         compute L·D²
  ffn    : mem 4·L·D·b                       compute 8·L·D²

``cache_mode``:
  "paper"   — per-head cache τ·D·b exactly as printed (§III.C says m_i(τ)
              includes "the K/V cache of attention head i plus its params").
  "precise" — per-head K+V is 2·τ·d·b (beyond-paper studies; DESIGN.md §7).

``compute_mode``:
  "paper"       — full-sequence reprocessing per interval, as in Table I.
  "incremental" — KV-cache-reusing decode: one new token costs
                  3·D·d + 2·L·d MACs per head (the TPU bridge uses this).

Communication volumes (Eq. 3/4): W_{i→proj} = L·d·b, W_{proj→ffn} = L·D·b
("paper"); incremental mode sends only the new token's activations
(d·b and D·b).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

FFN = "ffn"
PROJ = "proj"
HEAD = "head"


@dataclasses.dataclass(frozen=True)
class Block:
    index: int           # position in the block list
    kind: str            # head | ffn | proj
    head_id: int = -1    # for kind == head

    @property
    def name(self) -> str:
        return f"head{self.head_id}" if self.kind == HEAD else self.kind


def make_blocks(n_heads: int) -> List[Block]:
    blocks = [Block(i, HEAD, head_id=i) for i in range(n_heads)]
    blocks.append(Block(n_heads, PROJ))
    blocks.append(Block(n_heads + 1, FFN))
    return blocks


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Table-I resource usage for a single decoder layer.

    ``n_layers`` extends the single-layer model to the paper's "GPT-2/LLaMA
    scale" evaluation (§V.B): a *block* becomes the per-head column across
    all layers (the paper notes the approach "can be applied independently
    to each layer"; co-partitioning the columns is the natural multi-layer
    lift and is what reproduces the paper's GB-scale memory figures —
    EXPERIMENTS.md §Reproduction notes).  All memory/compute/communication
    volumes scale by n_layers; n_layers=1 is Table I exactly as printed.
    """

    d_model: int                 # D
    n_heads: int                 # h
    bytes_per_param: int = 2     # b
    L0: int = 64                 # prompt length
    lam: int = 1                 # λ tokens per interval
    n_layers: int = 1
    cache_mode: str = "paper"
    compute_mode: str = "paper"
    flops_per_mac: int = 2       # Table I counts MACs; FLOPs = 2x

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def seq_len(self, tau: int) -> int:
        return self.L0 + self.lam * tau

    # ----------------------------------------------------------- memory
    def memory(self, block: Block, tau: int) -> float:
        D, d, b = self.d_model, self.d_head, self.bytes_per_param
        L = self.seq_len(tau)
        if block.kind == HEAD:
            base = 3 * L * d * b + 3 * D * d * b
            if self.cache_mode == "paper":
                cache = tau * D * b
            else:
                cache = 2 * tau * d * b
            return float(self.n_layers * (base + cache))
        if block.kind == PROJ:
            return float(self.n_layers * L * D * b)
        return float(self.n_layers * 4 * L * D * b)  # ffn

    # ----------------------------------------------------------- compute
    def compute(self, block: Block, tau: int) -> float:
        D, d = self.d_model, self.d_head
        L = self.seq_len(tau)
        f = self.flops_per_mac * self.n_layers
        if self.compute_mode == "paper":
            if block.kind == HEAD:
                return float(f * (3 * L * D * d + L * L * d))
            if block.kind == PROJ:
                return float(f * (L * D * D))
            return float(f * (8 * L * D * D))
        # incremental: only the λ new tokens are processed
        n = self.lam
        if block.kind == HEAD:
            return float(f * n * (3 * D * d + 2 * L * d))
        if block.kind == PROJ:
            return float(f * n * (D * D))
        return float(f * n * (8 * D * D))

    # ------------------------------------------------------ communication
    def head_to_proj_bytes(self, tau: int) -> float:
        d, b = self.d_head, self.bytes_per_param
        L = self.seq_len(tau)
        n = L if self.compute_mode == "paper" else self.lam
        return float(self.n_layers * n * d * b)

    def proj_to_ffn_bytes(self, tau: int) -> float:
        D, b = self.d_model, self.bytes_per_param
        L = self.seq_len(tau)
        n = L if self.compute_mode == "paper" else self.lam
        return float(self.n_layers * n * D * b)

    def input_bytes(self, tau: int) -> float:
        """Controller -> head-device token embeddings."""
        D, b = self.d_model, self.bytes_per_param
        n = self.seq_len(tau) if self.compute_mode == "paper" else self.lam
        return float(n * D * b)

    # vectors over the standard block list -----------------------------------
    def memory_vector(self, blocks: Sequence[Block], tau: int):
        import numpy as np
        return np.array([self.memory(bl, tau) for bl in blocks])

    def compute_vector(self, blocks: Sequence[Block], tau: int):
        import numpy as np
        return np.array([self.compute(bl, tau) for bl in blocks])
