"""Delay model — Eq. (2)–(7) of the paper, generalized to a per-layer
block graph.

A placement is an int array ``place[block_index] -> device``.

Single layer (Eq. 6, with the natural completion of the pipeline: proj and
ffn processing included — the paper's equation lists the communication
terms explicitly and §III.E(b) defines processing delays for *every*
block; ``strict_eq6=True`` reproduces the bare printed form):

  D_T = max_{i∈H}( D_in→d(i) + D_proc(i) + D_{d(i)→d(proj)} )
        [+ D_proc(proj)] + D_{d(proj)→d(ffn)} [+ D_proc(ffn)]

Multi-layer (``make_blocks(h, n_layers)`` graphs): one decode token
traverses the layers sequentially — there is no intra-token pipelining —
so the total is the layer-composed critical path

  D_T = Σ_l D_layer(l)

where D_layer(l) is Eq. 6 applied to layer l's blocks with layer l's input
stage replaced by the inter-layer edge: layer 0's heads receive the token
embeddings from the controller (``input_bytes``), layer l>0's heads
receive the previous layer's output from d(ffn(l-1))
(``interlayer_bytes``).  Because the layers execute back-to-back, every
directed link serializes all layers' transfers and every device runs all
layers' resident blocks sequentially — the cross-layer sharing shows up as
the Σ_l composition, and the intra-layer sharing as Eq. 6's per-link /
per-device sums.  With n_layers=1 the loop body is the original Eq. 6
arithmetic, bit-for-bit.

Concurrency semantics (§III.E/F), per layer:
 - compute: blocks co-located on a device run sequentially — a head's
   processing term uses the *sum* of that layer's head compute on its
   device;
 - links: transfers sharing a directed link (j,k) are serialized — each
   head's comm term uses the summed volume on its link.  The inter-layer
   broadcast is one transfer per destination device (co-located heads
   share it), matching the controller-input convention.

Migration (Eq. 2/7): D_mig = Σ_i m_i(τ-1)/R_{j,k}(τ), serialized per link
— unchanged: per-layer blocks each contribute their single-layer
footprint.

Pipelined decode (beyond the printed model; Model-Distributed Inference,
arXiv 2505.18164, and the comm/compute overlap accounting of arXiv
2211.05102): with per-layer placements, consecutive decode tokens of
*different* requests can occupy layer-disjoint device sets concurrently.
``pipelined_inference_delay`` models K in-flight tokens: the first token
pays the full sequential critical path D_T (pipeline fill), every further
token is admitted one steady-state interval B later, where B is the
busiest single resource's per-token busy time (per-device compute and
per-directed-link transfer serialization are preserved — a resource can
only serve one token's work at a time).  Per-token amortized delay:

  D_pipe(K) = (D_T + (K-1)·B) / K,   B = min(bottleneck, D_T)

K=1 is bit-for-bit ``inference_delay``.  B is clamped to D_T because Eq. 6's
max-over-heads form can under-serialize transfers in *different* head
chains sharing one directed link; operationally a pipeline can always
degrade to sequential issue, so the steady-state interval never exceeds
D_T — which also makes D_pipe(K) ≤ D_T an invariant for every K ≥ 1.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.blocks import Block, CostModel, graph_of
from repro.core.network import DeviceNetwork


def _rate(net: DeviceNetwork, j: int, k: int) -> float:
    if j == k:
        return np.inf
    return float(net.bandwidth[j, k])


def _cdiv(x: float, rate: float) -> float:
    """Compute-time division pricing a dead device (C_j = 0) as +inf
    without tripping numpy's divide-by-zero warning: a placement that
    still references an inactive device has unbounded delay."""
    return float(x) / float(rate) if rate > 0.0 else np.inf


def _expert_stage(g, l, place, cost, tau):
    """Per-device (load fraction, summed compute) of layer l's expert
    blocks: the router fan-out/combine structure the delay model prices.

    Zero-load slots contribute nothing (no tokens are routed there); the
    per-device compute is summed BEFORE the single divide by the device
    rate so a co-located uniform-load expert set prices bit-for-bit like
    the dense ffn it collapses to."""
    agg: dict = {}
    for eb in g.experts[l]:
        ld = cost.expert_load(eb)
        if ld == 0.0:
            continue
        d = int(place[eb.index])
        fr, cp = agg.get(d, (0.0, 0.0))
        agg[d] = (fr + ld, cp + cost.compute(eb, tau))
    return agg


def inference_delay(place: np.ndarray, blocks: Sequence[Block],
                    cost: CostModel, net: DeviceNetwork, tau: int,
                    *, strict_eq6: bool = False) -> float:
    """D_T(τ) for placement ``place``: Eq. 6 per layer, composed along the
    inter-layer edges (see module docstring)."""
    g = graph_of(blocks)
    total = 0.0
    # layer 0: token embeddings from the controller; expert layers hand a
    # (device, load fraction) SOURCE LIST to the next layer's heads — the
    # router combine — which the dense path degenerates to as [(ffn, 1.0)]
    sources = [(net.controller, 1.0)]
    w_in = cost.input_bytes(tau)
    w_head = cost.head_to_proj_bytes(tau)
    for l in range(g.n_layers):
        heads = g.heads[l]
        d_proj = int(place[g.proj[l].index])

        # per-device summed head compute (sequential sharing)
        head_compute_on = np.zeros(net.n_devices)
        for h in heads:
            head_compute_on[place[h.index]] += cost.compute(h, tau)
        # per-link summed head->proj volume (serialized sharing)
        vol_to_proj = np.zeros(net.n_devices)
        for h in heads:
            vol_to_proj[place[h.index]] += w_head

        worst = 0.0
        for h in heads:
            j = int(place[h.index])
            t_in = sum(fr * w_in / _rate(net, s, j) for s, fr in sources)
            t_proc = _cdiv(head_compute_on[j], net.compute_avail[j])
            t_out = vol_to_proj[j] / _rate(net, j, d_proj)
            worst = max(worst, t_in + t_proc + t_out)

        total += worst
        if not strict_eq6:
            total += _cdiv(cost.compute(g.proj[l], tau), net.compute_avail[d_proj])
        if g.ffn[l] is not None:
            d_ffn = int(place[g.ffn[l].index])
            total += cost.proj_to_ffn_bytes(tau) / _rate(net, d_proj, d_ffn)
            if not strict_eq6:
                total += _cdiv(cost.compute(g.ffn[l], tau),
                               net.compute_avail[d_ffn])
            sources = [(d_ffn, 1.0)]
        else:
            # expert stage: router fan-out (load-fraction-scaled
            # proj->expert transfer) + per-device expert compute, run in
            # parallel across expert devices -> the stage is the slowest
            # device's (transfer, compute) pair, added as two terms to
            # keep the dense float association when collapsed
            agg = _expert_stage(g, l, place, cost, tau)
            w_p2f = cost.proj_to_ffn_bytes(tau)
            stage_t = stage_c = 0.0
            stage = -1.0
            for d in sorted(agg):
                fr, cp = agg[d]
                t_x = fr * w_p2f / _rate(net, d_proj, d)
                t_c = 0.0 if strict_eq6 else _cdiv(cp, net.compute_avail[d])
                if t_x + t_c > stage:
                    stage, stage_t, stage_c = t_x + t_c, t_x, t_c
            total += stage_t
            if not strict_eq6:
                total += stage_c
            sources = [(d, agg[d][0]) for d in sorted(agg)]
        w_in = cost.interlayer_bytes(tau)
    return float(total)


def resource_busy_times(place: np.ndarray, blocks: Sequence[Block],
                        cost: CostModel, net: DeviceNetwork, tau: int,
                        *, strict_eq6: bool = False
                        ) -> tuple[np.ndarray, dict]:
    """Per-token busy time of every resource under ``place``: seconds each
    device computes and each directed link transfers for ONE token's
    traversal of all layers.  These are the §III.E serialization
    constraints expressed as steady-state pipeline occupancies: a stream of
    in-flight tokens cannot be admitted faster than the busiest resource
    drains one token's share.

    Returns ``(device_busy (V,), link_busy {(j, k): seconds})`` with
    same-device transfers omitted (rate ∞, zero busy either way).
    """
    g = graph_of(blocks)
    dev_busy = np.zeros(net.n_devices)
    link_busy: dict = {}

    def add_link(j: int, k: int, seconds: float):
        if j != k and seconds > 0.0:
            link_busy[(j, k)] = link_busy.get((j, k), 0.0) + seconds

    sources = [(net.controller, 1.0)]
    w_in = cost.input_bytes(tau)
    w_head = cost.head_to_proj_bytes(tau)
    for l in range(g.n_layers):
        heads = g.heads[l]
        d_proj = int(place[g.proj[l].index])
        head_devs = set()
        for h in heads:
            j = int(place[h.index])
            head_devs.add(j)
            dev_busy[j] += _cdiv(cost.compute(h, tau), net.compute_avail[j])
            add_link(j, d_proj, w_head / _rate(net, j, d_proj))
        # inter-layer broadcast: one transfer per destination device
        # (co-located heads share it — the controller-input convention);
        # expert layers fan in from every expert-hosting source device
        # with its load fraction's share of the activation
        for s, fr in sources:
            for j in sorted(head_devs):
                add_link(s, j, fr * w_in / _rate(net, s, j))
        if not strict_eq6:
            dev_busy[d_proj] += _cdiv(cost.compute(g.proj[l], tau),
                                      net.compute_avail[d_proj])
        if g.ffn[l] is not None:
            d_ffn = int(place[g.ffn[l].index])
            if not strict_eq6:
                dev_busy[d_ffn] += _cdiv(cost.compute(g.ffn[l], tau),
                                         net.compute_avail[d_ffn])
            add_link(d_proj, d_ffn,
                     cost.proj_to_ffn_bytes(tau) / _rate(net, d_proj, d_ffn))
            sources = [(d_ffn, 1.0)]
        else:
            agg = _expert_stage(g, l, place, cost, tau)
            w_p2f = cost.proj_to_ffn_bytes(tau)
            for d in sorted(agg):
                fr, cp = agg[d]
                if not strict_eq6:
                    dev_busy[d] += _cdiv(cp, net.compute_avail[d])
                add_link(d_proj, d, fr * w_p2f / _rate(net, d_proj, d))
            sources = [(d, agg[d][0]) for d in sorted(agg)]
        w_in = cost.interlayer_bytes(tau)
    return dev_busy, link_busy


def pipeline_bottleneck(place: np.ndarray, blocks: Sequence[Block],
                        cost: CostModel, net: DeviceNetwork, tau: int,
                        *, strict_eq6: bool = False) -> float:
    """Steady-state per-token interval of a fully pipelined decode stream:
    the busiest single resource's busy time (unclamped — callers comparing
    against D_T should use ``pipelined_inference_delay``)."""
    dev_busy, link_busy = resource_busy_times(place, blocks, cost, net, tau,
                                              strict_eq6=strict_eq6)
    worst = float(dev_busy.max()) if dev_busy.size else 0.0
    if link_busy:
        worst = max(worst, max(link_busy.values()))
    return worst


def bottleneck_attribution(place: np.ndarray, blocks: Sequence[Block],
                           cost: CostModel, net: DeviceNetwork, tau: int,
                           *, strict_eq6: bool = False) -> tuple:
    """WHICH resource is the pipeline bottleneck: the argmax of
    ``resource_busy_times``, i.e. the single device or directed link whose
    per-token busy time bounds the steady-state pipelined rate.

    Returns ``("device", j, seconds)`` or ``("link", (j, k), seconds)``
    with ``seconds == pipeline_bottleneck(...)``.  A bottleneck-targeted
    search relieves exactly this resource first — moving blocks that
    neither compute on it nor transfer over it cannot shrink B."""
    dev_busy, link_busy = resource_busy_times(place, blocks, cost, net, tau,
                                              strict_eq6=strict_eq6)
    kind: str = "device"
    ident: object = int(np.argmax(dev_busy)) if dev_busy.size else 0
    busy = float(dev_busy.max()) if dev_busy.size else 0.0
    for lk, seconds in link_busy.items():
        if seconds > busy:
            kind, ident, busy = "link", lk, float(seconds)
    return kind, ident, busy


def pipelined_inference_delay(place: np.ndarray, blocks: Sequence[Block],
                              cost: CostModel, net: DeviceNetwork, tau: int,
                              *, k: int = 1,
                              strict_eq6: bool = False) -> float:
    """Per-token D_T with ``k`` tokens in flight over layer-disjoint stages
    (module docstring): (D_T + (k-1)·B)/k with B = min(bottleneck, D_T).

    ``k=1`` returns ``inference_delay`` bit-for-bit; D_pipe(k) ≤ D_T for
    every k ≥ 1, with equality exactly when nothing overlaps (single
    device, or B == D_T)."""
    if k < 1:
        raise ValueError(f"pipeline depth k must be >= 1, got {k}")
    d_t = inference_delay(place, blocks, cost, net, tau,
                          strict_eq6=strict_eq6)
    if k == 1:
        return d_t
    b = min(pipeline_bottleneck(place, blocks, cost, net, tau,
                                strict_eq6=strict_eq6), d_t)
    return float((d_t + (k - 1) * b) / k)


def migration_delay(prev: Optional[np.ndarray], place: np.ndarray,
                    blocks: Sequence[Block], cost: CostModel,
                    net: DeviceNetwork, tau: int) -> float:
    """Eq. (7): serialized migrations, block footprint at τ-1 (Eq. 2).

    With ``CostModel.page_size`` set (paged serving), the head-block
    footprint rounds the live token extent up to page granularity, so
    the priced migration bytes track allocated pages — the same unit
    the engine physically transfers — instead of the worst-case
    ``max_seq`` reservation."""
    if prev is None:
        return 0.0
    total = 0.0
    for bl in blocks:
        j, k = int(prev[bl.index]), int(place[bl.index])
        if j != k:
            total += cost.memory(bl, tau - 1) / _rate(net, j, k)
    return float(total)


def total_delay(prev: Optional[np.ndarray], place: np.ndarray,
                blocks: Sequence[Block], cost: CostModel,
                net: DeviceNetwork, tau: int, *,
                strict_eq6: bool = False) -> float:
    return inference_delay(place, blocks, cost, net, tau,
                           strict_eq6=strict_eq6) + \
        migration_delay(prev, place, blocks, cost, net, tau)


def pipelined_total_delay(prev: Optional[np.ndarray], place: np.ndarray,
                          blocks: Sequence[Block], cost: CostModel,
                          net: DeviceNetwork, tau: int, *, k: int = 1,
                          strict_eq6: bool = False) -> float:
    """D_pipe(k) + D_mig — the objective pipeline-aware policies/solvers
    optimize.  ``k=1`` is ``total_delay`` bit-for-bit."""
    return pipelined_inference_delay(place, blocks, cost, net, tau, k=k,
                                     strict_eq6=strict_eq6) + \
        migration_delay(prev, place, blocks, cost, net, tau)


def revert_unpaying_migrations(prev: Optional[np.ndarray],
                               place: np.ndarray, blocks: Sequence[Block],
                               cost: CostModel, net: DeviceNetwork,
                               tau: int, *, k: int = 1,
                               min_gain: float = 0.0) -> np.ndarray:
    """§III.G's migration filter, shared by the controller and
    ``ResourceAwarePolicy``: each migrated block is reverted to its
    previous device when keeping the move does not lower
    D_pipe(k) + D_mig by at least ``min_gain`` (k=1: D_T + D_mig).
    Reverts are only taken when memory-feasible, and NEVER back onto an
    inactive device — an evacuation off a dead device is mandatory, so
    the §III.G payback filter cannot undo it (the bypass ISSUE/§III.G
    requires is structural, not a flag)."""
    if prev is None:
        return place
    current = place.copy()
    cur_val = pipelined_total_delay(prev, current, blocks, cost, net, tau,
                                    k=k)
    for i in np.flatnonzero(current != prev):
        if not net.is_active(int(prev[i])):
            continue  # forced evacuation: reverting would re-kill the block
        trial = current.copy()
        trial[i] = prev[i]
        if not memory_feasible(trial, blocks, cost, net, tau):
            continue
        val = pipelined_total_delay(prev, trial, blocks, cost, net, tau,
                                    k=k)
        if val <= cur_val - min_gain:
            current, cur_val = trial, val
    return current


def memory_usage(place: np.ndarray, blocks: Sequence[Block],
                 cost: CostModel, net: DeviceNetwork, tau: int) -> np.ndarray:
    use = np.zeros(net.n_devices)
    for bl in blocks:
        use[place[bl.index]] += cost.memory(bl, tau)
    return use


def memory_feasible(place: np.ndarray, blocks: Sequence[Block],
                    cost: CostModel, net: DeviceNetwork, tau: int) -> bool:
    """Feasible against the *usable* memory view: observed availability,
    zero on inactive devices — so any placement still referencing a dead
    device is infeasible by construction."""
    return bool(np.all(memory_usage(place, blocks, cost, net, tau)
                       <= net.mem_usable() + 1e-9))
