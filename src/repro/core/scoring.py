"""Scoring function S(i,j,τ) — paper §IV.A(a), generalized per-layer.

  S(i,j,τ) = max{ m_i(τ)/M_j(τ),  b_i(τ)/C_j(τ)·(1/T_budget),  CommFactor }

The paper leaves two scalings implicit; we make them explicit and testable:

 - the compute ratio b_i/C_j has units of seconds, while m_i/M_j is
   dimensionless.  A device is "individually feasible" when S <= 1, so the
   time-like terms are normalized by ``deadline`` — the wall-clock budget of
   one interval (the paper sizes intervals "on the order of a few seconds";
   default 5 s, exposed as a parameter and swept in the tests).

 - CommFactor(i,j,τ) "approximates data transfer times if i must exchange
   information with blocks on different devices".  On a per-layer block
   graph every counterpart is layer-local except the inter-layer edges:
   head(l,i) receives its input from ffn(l-1) (the controller for l=0) and
   sends to proj(l); proj(l) takes the max of inbound-head and
   outbound-ffn transfers; ffn(l) the max of the inbound transfer and the
   outbound ffn(l) → head(l+1,·) activation broadcast — all normalized by
   the same deadline.  Counterpart devices are read from the *previous*
   placement (the controller's best current knowledge).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.blocks import Block, CostModel, EXPERT, HEAD, PROJ, graph_of
from repro.core.network import DeviceNetwork


def comm_factor(block: Block, j: int, blocks: Sequence[Block],
                prev_place: Optional[np.ndarray], cost: CostModel,
                net: DeviceNetwork, tau: int, deadline: float) -> float:
    def rate(a, b):
        return np.inf if a == b else float(net.bandwidth[a, b])

    g = graph_of(blocks)
    l = block.layer

    def dev(b: Block) -> int:
        """Counterpart device, -1 when unknown.  ``prev_place`` may be a
        partial view (entries -1): the assigner overlays its tentative
        in-round placement on the previous interval's — the controller's
        best current knowledge (§IV.A(a)) — so the first interval is not
        comm-blind for counterparts already placed this round."""
        if prev_place is None:
            return -1
        return int(prev_place[b.index])

    if block.kind == HEAD:
        t = 0.0
        if l == 0:
            t += cost.input_bytes(tau) / rate(net.controller, j)
        else:
            # inbound activation: the dense ffn, or the load-weighted
            # expert combine fan-in (sources with unknown devices skipped)
            for src_bl in g.out_blocks(l - 1):
                src = dev(src_bl)
                if src < 0:
                    continue
                fr = 1.0 if src_bl.kind != EXPERT \
                    else cost.expert_load(src_bl)
                t += fr * cost.interlayer_bytes(tau) / rate(src, j)
        proj_dev = dev(g.proj[l])
        if proj_dev >= 0:
            t += cost.head_to_proj_bytes(tau) / rate(j, proj_dev)
        return t / deadline
    if block.kind == PROJ:
        head_devs = set(d for d in (dev(h) for h in g.heads[l]) if d >= 0)
        t_in = cost.head_to_proj_bytes(tau) * cost.n_heads  # worst-case inbound
        t = 0.0
        if head_devs:
            t = t_in / min(rate(h_dev, j) for h_dev in head_devs)
        for out_bl in g.out_blocks(l):
            out_dev = dev(out_bl)
            if out_dev < 0:
                continue
            fr = 1.0 if out_bl.kind != EXPERT else cost.expert_load(out_bl)
            t = max(t, fr * cost.proj_to_ffn_bytes(tau) / rate(j, out_dev))
        return t / deadline
    if block.kind == EXPERT:
        # router fan-out in (load-fraction share of the proj activation),
        # combine out (same share of the next layer's activation broadcast)
        fr = cost.expert_load(block)
        t = 0.0
        proj_dev = dev(g.proj[l])
        if proj_dev >= 0:
            t = fr * cost.proj_to_ffn_bytes(tau) / rate(proj_dev, j)
        if l + 1 < g.n_layers:
            next_devs = [rate(j, d) for d in (dev(h) for h in g.heads[l + 1])
                         if d >= 0]
            if next_devs:
                t = max(t, fr * cost.interlayer_bytes(tau) / min(next_devs))
        return t / deadline
    # ffn: inbound from proj(l), outbound broadcast to layer l+1's heads
    t = 0.0
    proj_dev = dev(g.proj[l])
    if proj_dev >= 0:
        t = cost.proj_to_ffn_bytes(tau) / rate(proj_dev, j)
    if l + 1 < g.n_layers:
        next_devs = [rate(j, d) for d in (dev(h) for h in g.heads[l + 1])
                     if d >= 0]
        if next_devs:
            t = max(t, cost.interlayer_bytes(tau) / min(next_devs))
    return t / deadline


def score(block: Block, j: int, blocks: Sequence[Block],
          prev_place: Optional[np.ndarray], cost: CostModel,
          net: DeviceNetwork, tau: int, *, deadline: float = 5.0,
          mem_used: Optional[np.ndarray] = None,
          compute_used: Optional[np.ndarray] = None) -> float:
    """S(i,j,τ).  ``mem_used``/``compute_used`` optionally subtract already-
    assigned load on j (the per-block score in the paper is load-free; the
    algorithm's constraint check handles concurrency — §IV.A)."""
    if not net.is_active(j):
        # inactive device: no block may land here — enforced, not priced
        return np.inf
    mem_cap = net.mem_avail[j] - (0.0 if mem_used is None else mem_used[j])
    if mem_cap <= 0:
        return np.inf
    comp_avail = net.compute_avail[j]
    if comp_avail <= 0:
        return np.inf
    mem_term = cost.memory(block, tau) / mem_cap
    comp_term = (cost.compute(block, tau) +
                 (0.0 if compute_used is None else compute_used[j])) \
        / comp_avail / deadline
    cf = comm_factor(block, j, blocks, prev_place, cost, net, tau, deadline)
    return float(max(mem_term, comp_term, cf))


def score_matrix(blocks: Sequence[Block], prev_place: Optional[np.ndarray],
                 cost: CostModel, net: DeviceNetwork, tau: int,
                 *, deadline: float = 5.0) -> np.ndarray:
    """(|B|, |V|) matrix of S(i,j,τ)."""
    S = np.empty((len(blocks), net.n_devices))
    for bl in blocks:
        for j in range(net.n_devices):
            S[bl.index, j] = score(bl, j, blocks, prev_place, cost, net, tau,
                                   deadline=deadline)
    return S
