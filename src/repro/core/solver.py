"""Exact solvers for the small-scale evaluation (paper §V.C).

``exact_myopic``  — exhaustive search over all |V|^|B| placements at one
interval, minimizing D_T(τ) + D_mig(τ) under the memory constraint: the
optimal *myopic* decision the heuristic approximates.  Enumerable only up
to ``MAX_MYOPIC_PLACEMENTS`` (= 10^6) placements; larger instances —
which per-layer block graphs reach quickly, |B| = n_layers·(h+2) — raise
``ValueError`` instead of hanging combinatorially.

``exact_horizon`` — full-horizon DP over (interval, placement) when a priori
resource knowledge is assumed (§III.G), used only for very small instances;
the state space is |V|^|B| per stage and each stage is O(states²), so the
cap is the tighter ``MAX_HORIZON_STATES`` (= 4096 states).
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import Block, CostModel
from repro.core.delay import memory_feasible, pipelined_total_delay
from repro.core.network import DeviceNetwork

MAX_MYOPIC_PLACEMENTS = 1_000_000
MAX_HORIZON_STATES = 4096


def _check_enumerable(n_blocks: int, n_devices: int, limit: int, who: str):
    """Refuse instances whose |V|^|B| enumeration exceeds ``limit``."""
    if n_devices ** n_blocks > limit:
        raise ValueError(
            f"{who}: |V|^|B| = {n_devices}^{n_blocks} placements exceed the "
            f"enumerable limit of {limit}. Exact solvers only cover small "
            f"layer counts — per-layer graphs have |B| = n_layers*(h+2); "
            f"use ResourceAwareAssigner for larger instances.")


def _all_placements(n_blocks: int, n_devices: int):
    for combo in itertools.product(range(n_devices), repeat=n_blocks):
        yield np.array(combo, dtype=int)


def exact_myopic(blocks: Sequence[Block], cost: CostModel,
                 net: DeviceNetwork, tau: int,
                 prev: Optional[np.ndarray] = None,
                 *, strict_eq6: bool = False, pipeline_k: int = 1
                 ) -> Tuple[Optional[np.ndarray], float]:
    """``pipeline_k`` > 1 minimizes D_pipe(K) + D_mig (the steady-state
    pipelined objective); the default is the paper's D_T + D_mig."""
    _check_enumerable(len(blocks), net.n_devices, MAX_MYOPIC_PLACEMENTS,
                      "exact_myopic")
    best, best_val = None, np.inf
    for place in _all_placements(len(blocks), net.n_devices):
        if not memory_feasible(place, blocks, cost, net, tau):
            continue
        val = pipelined_total_delay(prev, place, blocks, cost, net, tau,
                                    k=pipeline_k, strict_eq6=strict_eq6)
        if val < best_val:
            best, best_val = place.copy(), val
    return best, best_val


def exact_horizon(blocks: Sequence[Block], cost: CostModel,
                  nets: List[DeviceNetwork], *, strict_eq6: bool = False,
                  pipeline_k: int = 1) -> Tuple[List[np.ndarray], float]:
    """DP over intervals 1..T given per-interval resource snapshots.
    ``pipeline_k`` > 1 prices each stage at D_pipe(K) + D_mig."""
    _check_enumerable(len(blocks), nets[0].n_devices, MAX_HORIZON_STATES,
                      "exact_horizon")
    states = [p for p in _all_placements(len(blocks), nets[0].n_devices)]
    n = len(states)
    INF = np.inf
    # stage 1: no migration cost
    val = np.full(n, INF)
    parent = np.full((len(nets), n), -1, dtype=int)
    for s, p in enumerate(states):
        if memory_feasible(p, blocks, cost, nets[0], 1):
            val[s] = pipelined_total_delay(None, p, blocks, cost, nets[0], 1,
                                           k=pipeline_k,
                                           strict_eq6=strict_eq6)
    for t in range(1, len(nets)):
        tau = t + 1
        new_val = np.full(n, INF)
        for s, p in enumerate(states):
            if not memory_feasible(p, blocks, cost, nets[t], tau):
                continue
            for s0, p0 in enumerate(states):
                if val[s0] == INF:
                    continue
                v = val[s0] + pipelined_total_delay(
                    p0, p, blocks, cost, nets[t], tau,
                    k=pipeline_k, strict_eq6=strict_eq6)
                if v < new_val[s]:
                    new_val[s] = v
                    parent[t, s] = s0
        val = new_val
    s = int(np.argmin(val))
    best_total = float(val[s])
    path = [states[s]]
    for t in range(len(nets) - 1, 0, -1):
        s = int(parent[t, s])
        path.append(states[s])
    path.reverse()
    return path, best_total
