"""Exact solvers for the small-scale evaluation (paper §V.C).

``exact_myopic``  — exhaustive search over all |V|^|B| placements at one
interval, minimizing D_T(τ) + D_mig(τ) under the memory constraint: the
optimal *myopic* decision the heuristic approximates.  Enumerable only up
to ``MAX_MYOPIC_PLACEMENTS`` (= 10^6) placements; larger instances —
which per-layer block graphs reach quickly, |B| = n_layers·(h+2) — raise
``ValueError`` instead of hanging combinatorially.

``exact_horizon`` — full-horizon DP over (interval, placement) when a priori
resource knowledge is assumed (§III.G), used only for very small instances;
the state space is |V|^|B| per stage and each stage is O(states²), so the
cap is the tighter ``MAX_HORIZON_STATES`` (= 4096 states).

``objective="bottleneck"`` is the parity hook for the bottleneck-targeted
placement search (``ResourceAwarePolicy(search="bottleneck")``): instead of
the scalar delay objective, placements are compared on the lexicographic
pair ``(min(B, D_T) + D_mig, D_T + D_mig)`` where B is the busiest
resource's per-token busy time (``delay.pipeline_bottleneck``) — minimize
the steady-state bottleneck first, break exact ties on the paper's myopic
objective.  Lexicographic pairs form a totally ordered group under
component-wise addition, so the horizon DP's Bellman recursion stays
valid.  The returned value is the primary (bottleneck) component.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import Block, CostModel
from repro.core.delay import (inference_delay, memory_feasible,
                              migration_delay, pipeline_bottleneck,
                              pipelined_total_delay)
from repro.core.network import DeviceNetwork

MAX_MYOPIC_PLACEMENTS = 1_000_000
MAX_HORIZON_STATES = 4096

OBJECTIVES = ("delay", "bottleneck")


def _check_enumerable(n_blocks: int, n_devices: int, limit: int, who: str):
    """Refuse instances whose |V|^|B| enumeration exceeds ``limit``."""
    if n_devices ** n_blocks > limit:
        raise ValueError(
            f"{who}: |V|^|B| = {n_devices}^{n_blocks} placements exceed the "
            f"enumerable limit of {limit}. Exact solvers only cover small "
            f"layer counts — per-layer graphs have |B| = n_layers*(h+2); "
            f"use ResourceAwareAssigner for larger instances.")


def _check_objective(objective: str, who: str):
    if objective not in OBJECTIVES:
        raise ValueError(f"{who}: objective must be one of {OBJECTIVES}, "
                         f"got {objective!r}")


def _all_placements(n_blocks: int, devices):
    """Enumerate placements over an explicit device-id list — the active
    view, so a shrunk/grown device set reuses the same enumeration.  An
    int is accepted as shorthand for ``range(devices)``."""
    if isinstance(devices, (int, np.integer)):
        devices = range(int(devices))
    for combo in itertools.product(devices, repeat=n_blocks):
        yield np.array(combo, dtype=int)


def _bottleneck_value(prev, place, blocks, cost, net, tau, *,
                      strict_eq6: bool) -> Tuple[float, float]:
    """(min(B, D_T) + D_mig, D_T + D_mig): bottleneck-first, tie-broken by
    the paper's myopic objective."""
    d_t = inference_delay(place, blocks, cost, net, tau,
                          strict_eq6=strict_eq6)
    b = min(pipeline_bottleneck(place, blocks, cost, net, tau,
                                strict_eq6=strict_eq6), d_t)
    d_mig = migration_delay(prev, place, blocks, cost, net, tau)
    return (b + d_mig, d_t + d_mig)


def exact_myopic(blocks: Sequence[Block], cost: CostModel,
                 net: DeviceNetwork, tau: int,
                 prev: Optional[np.ndarray] = None,
                 *, strict_eq6: bool = False, pipeline_k: int = 1,
                 objective: str = "delay"
                 ) -> Tuple[Optional[np.ndarray], float]:
    """``pipeline_k`` > 1 minimizes D_pipe(K) + D_mig (the steady-state
    pipelined objective); the default is the paper's D_T + D_mig.
    ``objective="bottleneck"`` minimizes the busiest resource instead
    (module docstring) and returns its busy time (+ D_mig) as the value."""
    _check_objective(objective, "exact_myopic")
    _check_enumerable(len(blocks), net.n_active, MAX_MYOPIC_PLACEMENTS,
                      "exact_myopic")
    best, best_val = None, None
    for place in _all_placements(len(blocks), list(net.active_ids)):
        if not memory_feasible(place, blocks, cost, net, tau):
            continue
        if objective == "bottleneck":
            val: tuple = _bottleneck_value(prev, place, blocks, cost, net,
                                           tau, strict_eq6=strict_eq6)
        else:
            val = (pipelined_total_delay(prev, place, blocks, cost, net, tau,
                                         k=pipeline_k,
                                         strict_eq6=strict_eq6),)
        if best_val is None or val < best_val:
            best, best_val = place.copy(), val
    if best is None:
        return None, np.inf
    return best, float(best_val[0])


def exact_horizon(blocks: Sequence[Block], cost: CostModel,
                  nets: List[DeviceNetwork], *, strict_eq6: bool = False,
                  pipeline_k: int = 1, objective: str = "delay"
                  ) -> Tuple[List[np.ndarray], float]:
    """DP over intervals 1..T given per-interval resource snapshots.
    ``pipeline_k`` > 1 prices each stage at D_pipe(K) + D_mig;
    ``objective="bottleneck"`` prices it at the lexicographic bottleneck
    pair instead (sums of pairs compare lexicographically, so the Bellman
    recursion is unchanged)."""
    _check_objective(objective, "exact_horizon")
    _check_enumerable(len(blocks), nets[0].n_active, MAX_HORIZON_STATES,
                      "exact_horizon")

    def stage_val(prev, place, net, tau) -> tuple:
        if objective == "bottleneck":
            return _bottleneck_value(prev, place, blocks, cost, net, tau,
                                     strict_eq6=strict_eq6)
        return (pipelined_total_delay(prev, place, blocks, cost, net, tau,
                                      k=pipeline_k, strict_eq6=strict_eq6),)

    def add(a: tuple, b: tuple) -> tuple:
        return tuple(x + y for x, y in zip(a, b))

    states = [p for p in _all_placements(len(blocks),
                                         list(nets[0].active_ids))]
    n = len(states)
    # stage 1: no migration cost
    val: List[Optional[tuple]] = [None] * n
    parent = np.full((len(nets), n), -1, dtype=int)
    for s, p in enumerate(states):
        if memory_feasible(p, blocks, cost, nets[0], 1):
            val[s] = stage_val(None, p, nets[0], 1)
    for t in range(1, len(nets)):
        tau = t + 1
        new_val: List[Optional[tuple]] = [None] * n
        for s, p in enumerate(states):
            if not memory_feasible(p, blocks, cost, nets[t], tau):
                continue
            for s0, p0 in enumerate(states):
                if val[s0] is None:
                    continue
                v = add(val[s0], stage_val(p0, p, nets[t], tau))
                if new_val[s] is None or v < new_val[s]:
                    new_val[s] = v
                    parent[t, s] = s0
        val = new_val
    reachable = [s for s in range(n) if val[s] is not None]
    if not reachable:
        # no memory-feasible placement at the final stage: the horizon is
        # infeasible — report it as such instead of a garbage path
        return [], float("inf")
    s = min(reachable, key=lambda s: val[s])
    best_total = float(val[s][0])
    path = [states[s]]
    for t in range(len(nets) - 1, 0, -1):
        s = int(parent[t, s])
        path.append(states[s])
    path.reverse()
    return path, best_total
