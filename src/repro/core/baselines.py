"""Baseline partitioning policies (paper §V.A).

Greedy / Round-Robin / Static / Dynamic are the paper's simple baselines;
EdgeShard [1] and Galaxy [3] are the state-of-the-art comparisons. All share
the ``Policy`` interface: ``place(net, tau, prev) -> placement | None``.

EdgeShard  — layer-wise static sharding: each decoder *layer* is one block.
  With the paper's single-layer model the whole layer (all heads + proj +
  ffn) lands on one device, chosen once for the full horizon by maximizing
  (memory headroom x compute): no adaptation, no K/V-growth handling.

Galaxy     — static hybrid tensor+sequence parallelism: heads and ffn are
  split evenly over all devices once (round-robin over the sorted-by-compute
  device list); proj is co-located with the fastest device. Models Galaxy's
  tensor-parallel sharding of each shard's matmuls; static during decoding.

On a **per-layer block graph** (``layer_mode="graph"`` / multi-layer
``make_blocks``) the layer-range baselines place *actual* per-layer blocks
instead of aggregate math: EdgeShard maps its contiguous layer shards to
real placements (every block of a stage's layers on the stage device);
Galaxy spreads each stage's heads over its TP island.  Both are then
priced by the unified per-layer Eq.-6 delay model — the comparison
isolates the placement policy, exactly like the paper's simulator.
``ColumnCoPartitionPolicy`` exposes the old column lift as a policy on the
same graph, so per-layer head placement can be compared against column
co-partitioning under identical delay semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.algorithm import ResourceAwareAssigner
from repro.core.blocks import (Block, CostModel, graph_of,
                               make_blocks, replicate_placement)
from repro.core.network import DeviceNetwork


class Policy:
    name = "base"

    def __init__(self, blocks: Sequence[Block], cost: CostModel, **kw):
        self.blocks = list(blocks)
        self.cost = cost

    def place(self, net: DeviceNetwork, tau: int,
              prev: Optional[np.ndarray]) -> Optional[np.ndarray]:
        raise NotImplementedError


class ResourceAwarePolicy(Policy):
    """Algorithm 1 + the objective refinement the paper's controller step
    requires (§III.G: "minimizes D_T(τ) + D_mig(τ)"): each proposed block
    migration is kept only if it lowers the myopic objective — migrations
    whose delay exceeds their latency gain are reverted. Disable with
    ``migration_filter=False`` for the ablation.

    On per-layer block graphs a bounded best-improvement pass over the same
    objective follows (``refine_passes``, default 1 when the block list is
    multi-layer): Algorithm 1's load-aware score spreads same-kind blocks
    to balance *utilization*, but the layer-composed critical path is a
    *sum* of per-layer terms, so e.g. every layer's ffn belongs on the
    fastest feasible device — a move the score never proposes and the
    refinement finds.  Each refinement move must already pay for its own
    migration delay (it minimizes D_T + D_mig), the inherent anti-thrash
    term.

    ``pipeline_k`` > 1 switches the refinement/filter objective to
    D_pipe(K) + D_mig (delay.py's pipelined model): the policy then
    optimizes steady-state pipelined throughput — spreading layers over
    disjoint device sets to shrink the bottleneck resource — instead of
    the single-token critical path.  ``pipeline_k=1`` is the paper
    objective bit-for-bit.

    ``search="bottleneck"`` (with ``pipeline_k`` > 1) adds the
    bottleneck-targeted placement search on top: the Algorithm-1 + refine
    + filter result is further improved by ``algorithm.refine_bottleneck``
    (layer-chain moves interleaved with the per-block sweep, aimed at the
    argmax resource of ``resource_busy_times``, migrations amortized over
    ``amortize`` intervals instead of the myopic one-interval payback that
    left straggler rescues permanently refused), and compared against a
    refined ``stage_balanced_chain`` seed.  The returned placement's
    D_pipe(K) is never worse than the ``search="rescoring"`` result on the
    same inputs (refinement is monotone and the chain candidate is only
    adopted when it wins), and ``pipeline_k=1`` stays bit-for-bit the
    paper algorithm — the search only ever runs on the pipelined
    objective, where D_T + D_mig is the tie-break."""
    name = "resource-aware"

    SEARCH_MODES = ("rescoring", "bottleneck")

    def __init__(self, blocks, cost, *, deadline: float = 5.0,
                 migration_filter: bool = True,
                 refine_passes: Optional[int] = None,
                 pipeline_k: int = 1, search: str = "rescoring",
                 amortize: int = 16, chain_seed: bool = True,
                 search_rounds: int = 4, min_gain: float = 0.0, **kw):
        super().__init__(blocks, cost)
        if search not in self.SEARCH_MODES:
            raise ValueError(f"search must be one of {self.SEARCH_MODES}, "
                             f"got {search!r}")
        self.assigner = ResourceAwareAssigner(blocks, cost,
                                              deadline=deadline, **kw)
        self.migration_filter = migration_filter
        self.pipeline_k = pipeline_k
        self.search = search
        self.amortize = amortize
        self.chain_seed = chain_seed
        self.search_rounds = search_rounds
        self.min_gain = min_gain
        # chain re-seed memo: the ``prev`` placement the chain candidate
        # last LOST against.  While the incumbent is unchanged the seed
        # is deterministic in (blocks, cost) and the race re-runs to the
        # same verdict, so the whole seed+refine pass is skipped.
        self._chain_lost_to = None
        self.chain_reseeds = 0
        self.chain_reseed_skips = 0
        multi = graph_of(self.blocks).n_layers > 1
        self.refine_passes = (1 if multi else 0) \
            if refine_passes is None else refine_passes

    def _objective(self, prev, place, net, tau) -> float:
        """D_T + D_mig, or D_pipe(K) + D_mig when pipeline-aware."""
        from repro.core.delay import pipelined_total_delay
        return pipelined_total_delay(prev, place, self.blocks, self.cost,
                                     net, tau, k=self.pipeline_k)

    def _refine(self, prev, place, net, tau):
        """Best-improvement local search on the objective (memory-feasible
        single-block moves), at most ``refine_passes`` sweeps."""
        from repro.core.delay import memory_usage
        cur = place.copy()
        cur_val = self._objective(prev, cur, net, tau)
        mem = self.cost.memory_vector(self.blocks, tau)
        use = memory_usage(cur, self.blocks, self.cost, net, tau)
        for _ in range(self.refine_passes):
            improved = False
            for i in range(len(self.blocks)):
                src = int(cur[i])
                best_j, best_val = src, cur_val
                for j in net.active_ids:
                    if j == src or use[j] + mem[i] > net.mem_avail[j]:
                        continue
                    cur[i] = j
                    val = self._objective(prev, cur, net, tau)
                    if val < best_val - 1e-12:
                        best_j, best_val = j, val
                cur[i] = best_j
                if best_j != src:
                    use[src] -= mem[i]
                    use[best_j] += mem[i]
                    cur_val = best_val
                    improved = True
            if not improved:
                break
        return cur

    def place(self, net, tau, prev):
        placement, stats = self.assigner.assign(net, tau, prev)
        self.last_stats = stats
        if placement is None:
            return placement
        if self.refine_passes > 0:
            placement = self._refine(prev, placement, net, tau)
        if prev is not None and self.migration_filter:
            from repro.core.delay import revert_unpaying_migrations
            placement = revert_unpaying_migrations(
                prev, placement, self.blocks, self.cost, net, tau,
                k=self.pipeline_k, min_gain=self.min_gain)
        if self.search == "bottleneck" and self.pipeline_k > 1:
            placement = self._bottleneck_search(prev, placement, net, tau)
        return placement

    def _bottleneck_search(self, prev, base, net, tau):
        """The bottleneck-targeted search pass: refine the rescoring result
        toward the steady-state objective, race it against a refined
        stage-balanced chain seed, keep whichever wins on the amortized
        objective WITHOUT ever giving up the base result's D_pipe(K)."""
        from repro.core.algorithm import (_pipe_value, refine_bottleneck,
                                          stage_balanced_chain)
        k = self.pipeline_k
        cand = refine_bottleneck(prev, base, self.blocks, self.cost, net,
                                 tau, k=k, amortize=self.amortize,
                                 rounds=self.search_rounds)
        if not self.chain_seed:
            return cand
        if self._chain_lost_to is not None and prev is not None and \
                np.array_equal(prev, self._chain_lost_to):
            self.chain_reseed_skips += 1
            return cand
        self.chain_reseeds += 1
        seed = stage_balanced_chain(self.blocks, self.cost, net, tau,
                                    pipeline_k=k)
        if seed is None:
            return cand
        alt = refine_bottleneck(prev, seed, self.blocks, self.cost, net,
                                tau, k=k, amortize=self.amortize,
                                rounds=self.search_rounds)
        c_pipe, _, c_mig = _pipe_value(prev, cand, self.blocks, self.cost,
                                       net, tau, k)
        a_pipe, _, a_mig = _pipe_value(prev, alt, self.blocks, self.cost,
                                       net, tau, k)
        # adopt the chain only when it beats the base-derived candidate on
        # the amortized objective AND does not worsen D_pipe(K) — the
        # never-worse-than-rescoring guarantee survives either way
        if a_pipe <= c_pipe + 1e-15 and \
                self.amortize * a_pipe + a_mig < self.amortize * c_pipe + c_mig:
            self._chain_lost_to = None
            return alt
        self._chain_lost_to = None if prev is None else \
            np.asarray(prev).copy()
        return cand


class BottleneckAwarePolicy(ResourceAwarePolicy):
    """``ResourceAwarePolicy(search="bottleneck")`` under its own policy
    name, so benchmarks/simulators can A/B the bottleneck-targeted search
    against the ``pipeline_k``-rescoring default by name.  With
    ``pipeline_k=1`` it degenerates to the paper algorithm bit-for-bit
    (the search only exists on the pipelined objective)."""
    name = "bottleneck-aware"

    def __init__(self, blocks, cost, **kw):
        kw.setdefault("search", "bottleneck")
        super().__init__(blocks, cost, **kw)


class GreedyPolicy(Policy):
    """Sort blocks by descending demand; place on the first feasible device
    without re-checking feasibility in subsequent steps (§V.A)."""
    name = "greedy"

    def place(self, net, tau, prev):
        mem = self.cost.memory_vector(self.blocks, tau)
        order = np.argsort(-mem)
        place = np.zeros(len(self.blocks), dtype=int)
        for i in order:
            placed = False
            for j in net.active_ids:
                if mem[i] <= net.mem_avail[j]:
                    place[i] = int(j)     # no aggregate re-check: greedy
                    placed = True
                    break
            if not placed:
                place[i] = int(np.argmax(net.mem_usable()))
        return place


class RoundRobinPolicy(Policy):
    """Cyclic assignment ignoring resource requirements (§V.A)."""
    name = "round-robin"

    def place(self, net, tau, prev):
        act = net.active_ids
        return act[np.arange(len(self.blocks)) % len(act)]


class StaticPolicy(Policy):
    """One initial resource-aware assignment, never migrated (§V.A)."""
    name = "static"

    def __init__(self, blocks, cost, **kw):
        super().__init__(blocks, cost)
        self._inner = ResourceAwarePolicy(blocks, cost, **kw)
        self._frozen: Optional[np.ndarray] = None

    def place(self, net, tau, prev):
        if self._frozen is None:
            self._frozen = self._inner.place(net, tau, None)
        return self._frozen


class DynamicLayerPolicy(Policy):
    """Re-checks each interval but treats the layer as ONE block (§V.A):
    the entire layer migrates to the single best device."""
    name = "dynamic-layer"

    def place(self, net, tau, prev):
        mem_total = self.cost.memory_vector(self.blocks, tau).sum()
        comp_total = self.cost.compute_vector(self.blocks, tau).sum()
        best, best_t = None, np.inf
        for j in net.active_ids:
            j = int(j)
            if mem_total > net.mem_avail[j]:
                continue
            t = comp_total / net.compute_avail[j]
            if prev is not None and int(prev[0]) != j:
                # whole-layer migration over the slowest involved link
                t += mem_total / net.bandwidth[int(prev[0]), j]
            if t < best_t:
                best, best_t = j, t
        if best is None:
            best = int(np.argmax(net.mem_usable()))
        return np.full(len(self.blocks), best, dtype=int)


class _PipelinePolicy(Policy):
    """Shared machinery for the layer-sharding SOTA baselines.

    Both EdgeShard [1] and Galaxy [3] shard the model by *contiguous layer
    groups*; a single decode token flows through the stages sequentially —
    pipeline parallelism has no intra-token parallelism, which is exactly
    the weakness the paper exploits.  Subclasses set the stage structure.

    Two evaluation modes, keyed off the block list:

    - aggregate (single-layer column blocks): the stage structure cannot
      be expressed as a block placement, so this class provides its own
      per-step pipeline delay (``step_delay``) and per-device memory
      (``device_memory``) hooks the simulator consumes, plus the
      swap-stall overload semantics shared with Eq. 6-based policies.

    - per-layer graph (multi-layer ``make_blocks``): ``place`` returns the
      stage structure as an *actual* per-layer block placement
      (``aggregate_semantics`` is False) and the simulator prices it with
      the unified per-layer Eq.-6 delay model like every other policy.

    Per-layer costs are Table-I sums over one layer's blocks.
    """
    stages: list  # list of (device_list, n_layers_in_stage)

    def __init__(self, blocks, cost, **kw):
        super().__init__(blocks, cost)
        self._graph = graph_of(self.blocks)
        self.aggregate_semantics = self._graph.n_layers == 1
        self._layer_cost = dataclasses.replace(cost, n_layers=1)
        self._layer_blocks = self._graph.layer_blocks(0)
        self.stages = []
        # graph-mode block placement, computed ONCE with the stages: these
        # baselines are static during decoding, so the intra-stage layout
        # must not chase compute_avail fluctuations (that would charge the
        # static baseline spurious migration delay)
        self._frozen_place: Optional[np.ndarray] = None

    # stage layout --------------------------------------------------------
    def _stage_layers(self):
        """Consecutive layer ranges per stage: [(devs, [layers...])]."""
        out, nxt = [], 0
        for devs, n in self.stages:
            out.append((devs, list(range(nxt, nxt + n))))
            nxt += n
        return out

    def _graph_placement(self, net: DeviceNetwork) -> np.ndarray:
        """Materialize the stage structure as a per-layer block placement
        (graph mode only).  Subclasses refine intra-stage placement."""
        place = np.zeros(len(self.blocks), dtype=int)
        for devs, layer_ids in self._stage_layers():
            for l in layer_ids:
                for b in self._graph.layer_blocks(l):
                    place[b.index] = devs[0]
        return place

    # one layer's aggregate compute / memory ------------------------------
    def _layer_compute(self, tau: int) -> float:
        return float(sum(self._layer_cost.compute(b, tau)
                         for b in self._layer_blocks))

    def _layer_memory(self, tau: int) -> float:
        return float(sum(self._layer_cost.memory(b, tau)
                         for b in self._layer_blocks))

    def _boundary_bytes(self, tau: int) -> float:
        return self._layer_cost.proj_to_ffn_bytes(tau)  # activations D·b(·L)

    # simulator hooks ------------------------------------------------------
    def device_memory(self, net: DeviceNetwork, tau: int) -> np.ndarray:
        use = np.zeros(net.n_devices)
        per_layer = self._layer_memory(tau)
        for devs, n_layers in self.stages:
            share = per_layer * n_layers / len(devs)
            for j in devs:
                use[j] += share
        return use

    def step_delay(self, net: DeviceNetwork, tau: int) -> float:
        """Sequential pipeline traversal of one token."""
        t = 0.0
        per_layer = self._layer_compute(tau)
        prev_exit = net.controller
        for devs, n_layers in self.stages:
            # TP within the stage: compute split over members, bounded by the
            # slowest member; per-layer TP sync of 2 all-gathers of D·b over
            # the weakest intra-stage link (Galaxy's tensor parallelism).
            slowest = min(net.compute_avail[j] for j in devs)
            t += n_layers * per_layer / (len(devs) * slowest)
            if len(devs) > 1:
                intra = min(net.bandwidth[a, b] for a in devs for b in devs
                            if a != b)
                t += n_layers * 2 * self._boundary_bytes(tau) / intra
            entry = devs[0]
            if entry != prev_exit:
                t += self._boundary_bytes(tau) / net.bandwidth[prev_exit, entry]
            prev_exit = devs[-1]
        return t


class EdgeShardPolicy(_PipelinePolicy):
    """EdgeShard [1]: static layer-wise shards, one device per stage, layer
    counts proportional to device compute; device subset chosen once at τ=1
    to fit the τ=1 footprint (no K/V-growth adaptation — the paper's
    criticism)."""
    name = "edgeshard"

    def place(self, net, tau, prev):
        if not self.stages:
            L = self.cost.n_layers
            act = net.active_ids
            order = [int(j) for j in act[np.argsort(-net.compute_avail[act])]]
            mem_l1 = self._layer_memory(1)
            # smallest fast subset whose τ=1 memory fits
            chosen: list = []
            for j in order:
                chosen.append(j)
                cap = sum(net.mem_avail[k] for k in chosen)
                if cap >= L * mem_l1 and len(chosen) >= 2:
                    break
            speeds = np.array([net.compute_avail[j] for j in chosen])
            shares = np.maximum(1, np.round(L * speeds / speeds.sum())).astype(int)
            while shares.sum() > L:
                shares[np.argmax(shares)] -= 1
            while shares.sum() < L:
                shares[np.argmax(speeds)] += 1
            self.stages = [([j], int(s)) for j, s in zip(chosen, shares)]
        if not self.aggregate_semantics:
            # per-layer graph: the layer shards ARE a block placement —
            # every block of a stage's layers on the stage device
            if self._frozen_place is None:
                self._frozen_place = self._graph_placement(net)
            return self._frozen_place.copy()
        # representative block-level placement (metrics only): everything on
        # the first stage's device
        return np.full(len(self.blocks), self.stages[0][0][0], dtype=int)


class GalaxyPolicy(_PipelinePolicy):
    """Galaxy [3]: hybrid pipeline + tensor parallelism — devices grouped
    into TP islands of size ``tp``; contiguous layer shards proportional to
    island compute; static during decoding."""
    name = "galaxy"

    def __init__(self, blocks, cost, *, tp: int = 4, **kw):
        super().__init__(blocks, cost, **kw)
        self.tp = tp

    def place(self, net, tau, prev):
        if not self.stages:
            L = self.cost.n_layers
            act = net.active_ids
            order = [int(j) for j in act[np.argsort(-net.compute_avail[act])]]
            groups = [order[i:i + self.tp] for i in
                      range(0, len(order) - self.tp + 1, self.tp)]
            if not groups:
                groups = [order]
            agg = np.array([sum(net.compute_avail[j] for j in g)
                            for g in groups])
            shares = np.maximum(0, np.round(L * agg / agg.sum())).astype(int)
            while shares.sum() > L:
                shares[np.argmax(shares)] -= 1
            while shares.sum() < L:
                shares[np.argmax(agg)] += 1
            self.stages = [(g, int(s)) for g, s in zip(groups, shares) if s > 0]
        if not self.aggregate_semantics:
            # hybrid TP+PP as real blocks: each stage's heads round-robin
            # over its island, proj/ffn on the island's fastest member —
            # frozen with the stages (static during decoding)
            if self._frozen_place is None:
                place = np.zeros(len(self.blocks), dtype=int)
                for devs, layer_ids in self._stage_layers():
                    fastest = max(devs, key=lambda j: net.compute_avail[j])
                    for l in layer_ids:
                        for i, h in enumerate(self._graph.heads[l]):
                            place[h.index] = devs[i % len(devs)]
                        place[self._graph.proj[l].index] = fastest
                        for ob in self._graph.out_blocks(l):
                            place[ob.index] = fastest
                self._frozen_place = place
            return self._frozen_place.copy()
        return np.full(len(self.blocks), self.stages[0][0][0], dtype=int)


class ColumnCoPartitionPolicy(Policy):
    """The old ``layer_mode="columns"`` lift expressed as a policy over the
    per-layer block graph: Algorithm 1 runs on the single-layer column
    blocks (costs aggregated over all layers), and the resulting column
    placement is replicated to every layer — head i of *every* layer on one
    device, one shared proj/ffn device.  Evaluated under the same per-layer
    delay model as every other graph policy, this is the control arm the
    per-layer ``ResourceAwarePolicy`` must beat on heterogeneous-bandwidth
    networks (it cannot adapt placement per layer or shorten inter-layer
    hops)."""
    name = "column-copartition"

    def __init__(self, blocks, cost, **kw):
        super().__init__(blocks, cost)
        g = graph_of(self.blocks)
        self._n_per_layer = len(g.layer_blocks(0))
        col_cost = dataclasses.replace(cost, layer_mode="columns")
        self._col_blocks = make_blocks(cost.n_heads, 1, cost.n_experts,
                                       cost.expert_replicas)
        self._inner = ResourceAwarePolicy(self._col_blocks, col_cost, **kw)

    def place(self, net, tau, prev):
        # prev is column-replicated by construction: layer 0's slice is the
        # column placement
        prev_col = None if prev is None else \
            np.asarray(prev[:self._n_per_layer], dtype=int)
        col = self._inner.place(net, tau, prev_col)
        self.last_stats = getattr(self._inner, "last_stats", None)
        if col is None:
            return None
        return replicate_placement(col, self.blocks)


class LookaheadPolicy(ResourceAwarePolicy):
    """Beyond-paper: the paper's stated future work (§VI — "incorporate
    limited foresight ... predict resource availability ahead of time").

    Per-device EWMA + trend forecast of C_j over the next ``horizon``
    intervals; Algorithm 1 runs against the forecast *average* (placements
    stop chasing transient dips), and the migration filter amortizes the
    one-time migration cost over the horizon (a move pays if
    horizon·ΔD_T > D_mig instead of 1·ΔD_T > D_mig).
    """
    name = "lookahead"

    def __init__(self, blocks, cost, *, horizon: int = 8, ewma: float = 0.5,
                 **kw):
        super().__init__(blocks, cost, **kw)
        self.horizon = horizon
        self.ewma = ewma
        self._level: Optional[np.ndarray] = None
        self._trend: Optional[np.ndarray] = None

    def _forecast(self, net: DeviceNetwork) -> np.ndarray:
        obs = net.compute_avail.astype(float)
        if self._level is not None and len(self._level) != len(obs):
            self._level = None  # device joined: restart the forecast state
        if self._level is None:
            self._level = obs.copy()
            self._trend = np.zeros_like(obs)
        else:
            prev = self._level.copy()
            self._level = self.ewma * obs + (1 - self.ewma) * \
                (self._level + self._trend)
            self._trend = 0.3 * (self._level - prev) + 0.7 * self._trend
        # mean forecast over the horizon, clipped to physical bounds
        steps = np.arange(1, self.horizon + 1).mean()
        pred = self._level + steps * self._trend
        pred = np.clip(pred, 0.05 * net.compute_max, net.compute_max)
        # the clip floor must not resurrect an inactive device's forecast
        return np.where(net.active, pred, 0.0)

    def place(self, net, tau, prev):
        pred_net = net.copy()
        pred_net.compute_avail = self._forecast(net)
        placement, stats = self.assigner.assign(pred_net, tau, prev)
        self.last_stats = stats
        if placement is None or prev is None or not self.migration_filter:
            return placement
        from repro.core.delay import (inference_delay, memory_feasible,
                                      migration_delay)
        current = placement.copy()

        def amortized(pl):
            # horizon intervals of inference + one migration
            return self.horizon * inference_delay(
                pl, self.blocks, self.cost, pred_net, tau) + \
                migration_delay(prev, pl, self.blocks, self.cost,
                                pred_net, tau)

        cur_val = amortized(current)
        for i in np.flatnonzero(current != prev):
            trial = current.copy()
            trial[i] = prev[i]
            if not memory_feasible(trial, self.blocks, self.cost, net, tau):
                continue
            val = amortized(trial)
            if val <= cur_val:
                current, cur_val = trial, val
        return current


ALL_POLICIES = {
    p.name: p for p in (ResourceAwarePolicy, BottleneckAwarePolicy,
                        GreedyPolicy, RoundRobinPolicy,
                        StaticPolicy, DynamicLayerPolicy, EdgeShardPolicy,
                        GalaxyPolicy, ColumnCoPartitionPolicy,
                        LookaheadPolicy)
}
