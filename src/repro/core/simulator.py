"""Discrete-event simulator for token-by-token distributed inference
(paper §V.B): a controller gathers device/link state each interval τ, runs a
placement policy, applies migrations, and advances one generated token
(λ = 1 — the paper's worst-case migration stress).

Memory-overload semantics: a placement that over-runs M_j(τ) (static
policies under K/V growth) does not crash — the device *thrashes*: overflow
bytes are swapped at ``swap_bw`` (default 1 GB/s) once per interval, added
to that device's completion time.  This is the physical mechanism behind
EdgeShard/Galaxy's blow-up in the paper's Fig. 3/4.

Metrics per step: inference delay, migration delay, overload stall,
cumulative latency, per-device & total memory, #migrations — exactly the
quantities in Fig. 3 (latency vs n) and Fig. 4 (memory vs n).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.baselines import Policy
from repro.core.blocks import Block, CostModel
from repro.core.delay import (inference_delay, memory_usage,
                              migration_delay, pipeline_bottleneck,
                              pipelined_inference_delay)
from repro.core.network import DeviceNetwork


@dataclasses.dataclass
class StepRecord:
    tau: int
    d_inf: float
    d_mig: float
    d_overload: float
    cumulative: float
    mem_total: float
    mem_max_device: float
    n_migrations: int
    infeasible: bool
    # busiest-resource busy time (pipelined runs only, else 0): the
    # steady-state interval the bottleneck-targeted search minimizes —
    # lets benchmarks attribute a policy's throughput to B vs D_T.
    d_bneck: float = 0.0


@dataclasses.dataclass
class SimResult:
    policy: str
    steps: List[StepRecord]

    @property
    def total_latency(self) -> float:
        return self.steps[-1].cumulative if self.steps else np.inf

    @property
    def per_step_latency(self) -> np.ndarray:
        return np.array([s.d_inf + s.d_mig + s.d_overload for s in self.steps])

    @property
    def mem_total_series(self) -> np.ndarray:
        return np.array([s.mem_total for s in self.steps])

    @property
    def mem_max_series(self) -> np.ndarray:
        return np.array([s.mem_max_device for s in self.steps])

    @property
    def migrations(self) -> int:
        return sum(s.n_migrations for s in self.steps)

    @property
    def bottleneck_series(self) -> np.ndarray:
        """Per-step busiest-resource busy time (pipelined runs)."""
        return np.array([s.d_bneck for s in self.steps])


def overload_stall(place: np.ndarray, blocks: Sequence[Block],
                   cost: CostModel, net: DeviceNetwork, tau: int,
                   swap_bw: float = 1e9) -> float:
    use = memory_usage(place, blocks, cost, net, tau)
    overflow = np.maximum(use - net.mem_usable(), 0.0)
    return float(overflow.max() / swap_bw) if overflow.size else 0.0


def simulate(policy: Policy, blocks: Sequence[Block], cost: CostModel,
             net: DeviceNetwork, n_tokens: int, *,
             fluctuate: bool = True, swap_bw: float = 1e9,
             strict_eq6: bool = False, seed: Optional[int] = None,
             pipeline_k: int = 1,
             events: Optional[Sequence] = None) -> SimResult:
    """``pipeline_k`` > 1 prices each step at the amortized per-token
    pipelined delay D_pipe(K) — K tokens of different requests in flight
    over layer-disjoint stages — instead of the sequential D_T.
    ``pipeline_k=1`` is unchanged bit-for-bit.

    ``events`` injects device churn mid-run: an iterable of ``(tau, fn)``
    pairs; each ``fn(net)`` runs before the policy places at that
    interval (e.g. ``lambda net: net.fail(3)``)."""
    net = net.copy()
    if seed is not None:
        net.rng = np.random.default_rng(seed)
    by_tau: Dict[int, list] = {}
    for ev_tau, fn in (events or ()):
        by_tau.setdefault(int(ev_tau), []).append(fn)
    prev: Optional[np.ndarray] = None
    cumulative = 0.0
    records: List[StepRecord] = []
    for tau in range(1, n_tokens + 1):
        if fluctuate and tau > 1:
            net.step_background_load()
        for fn in by_tau.get(tau, ()):
            fn(net)
        place = policy.place(net, tau, prev)
        infeasible = place is None
        d_bneck = 0.0
        if infeasible:
            place = prev if prev is not None else \
                np.zeros(len(blocks), dtype=int)
        if hasattr(policy, "step_delay") and \
                getattr(policy, "aggregate_semantics", True):
            # aggregate pipeline baselines (EdgeShard/Galaxy on the
            # single-layer column model) carry their own delay and memory
            # semantics (baselines._PipelinePolicy); on a per-layer block
            # graph they emit real placements and fall through to the
            # unified per-layer delay model below
            d_mig = 0.0
            d_inf = policy.step_delay(net, tau)
            use = policy.device_memory(net, tau)
            overflow = np.maximum(use - net.mem_usable(), 0.0)
            d_ovl = float(overflow.max() / swap_bw)
            n_mig = 0
        else:
            d_mig = migration_delay(prev, place, blocks, cost, net, tau)
            if pipeline_k > 1:
                d_inf = pipelined_inference_delay(place, blocks, cost, net,
                                                  tau, k=pipeline_k,
                                                  strict_eq6=strict_eq6)
                d_bneck = pipeline_bottleneck(place, blocks, cost, net, tau,
                                              strict_eq6=strict_eq6)
            else:
                d_inf = inference_delay(place, blocks, cost, net, tau,
                                        strict_eq6=strict_eq6)
            d_ovl = overload_stall(place, blocks, cost, net, tau, swap_bw)
            n_mig = 0 if prev is None else int((prev != place).sum())
            use = memory_usage(place, blocks, cost, net, tau)
        cumulative += d_inf + d_mig + d_ovl
        records.append(StepRecord(
            tau=tau, d_inf=d_inf, d_mig=d_mig, d_overload=d_ovl,
            cumulative=cumulative, mem_total=float(use.sum()),
            mem_max_device=float(use.max()), n_migrations=n_mig,
            infeasible=infeasible, d_bneck=d_bneck))
        prev = place
    return SimResult(policy=policy.name, steps=records)


def compare_policies(policies: Dict[str, Policy], blocks, cost, net,
                     n_tokens: int, **kw) -> Dict[str, SimResult]:
    return {name: simulate(pol, blocks, cost, net, n_tokens, **kw)
            for name, pol in policies.items()}
