"""Repo-invariant AST lints (RPR0xx) — standalone, stdlib-only.

Each rule encodes a bug class a previous PR fixed by hand, so the class
cannot regress silently:

RPR001  PRNGKey reuse / loop-counter keys.  ``jax.random.PRNGKey`` inside
        a ``for``/``while`` body (same or correlated key every iteration)
        or keyed off a counter attribute (``PRNGKey(self.decode_steps)``
        — the PR 1 sampler bug).  Derive per-step keys with ``fold_in``
        from one seed instead.
RPR002  ``subprocess`` call whose literal ``env=`` dict drops
        ``JAX_PLATFORMS`` without inheriting ``os.environ`` — jax in the
        child probes accelerator plugins and hangs (PR 1 root cause).
RPR003  Broad ``except``/``except Exception`` that swallows the fault:
        the handler neither binds the exception nor uses it, so nothing
        (a migration-path ``applied``/``reason`` log, a monitor event)
        can record WHAT failed (PR 3's silent-skip class).
RPR004  Host round-trip (``float()``/``int()``/``.item()``/
        ``np.asarray``) on a per-step value inside a loop of a function
        that drives jitted calls — an implicit device sync in the decode
        hot loop.
RPR005  ``jax.jit`` over a state-carrying signature (``decode_step``,
        ``insert_slot``, ``prefill``, ``prefill_bucketed``) without
        ``donate_argnums``: every step materializes a second full KV
        cache — exactly the memory Algorithm 1 is partitioning.

Waivers: end the offending line (or the line above) with
``# rpr: ignore[RPR00N] -- reason``.  The reason is mandatory; a
reasonless waiver is itself reported (RPR000).  ``[RPR00N]`` may list
several comma-separated codes; omitting it waives every code on that
line.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from repro.analysis import Finding

SUBPROCESS_CALLS = {"run", "Popen", "check_output", "check_call", "call"}
STATEFUL_JIT_TARGETS = {"decode_step", "insert_slot", "prefill",
                        "prefill_bucketed"}
HOST_ROUNDTRIP_NAMES = {"float", "int"}
SEEDISH = re.compile(r"seed", re.I)
_WAIVER_RE = re.compile(
    r"#\s*rpr:\s*ignore(?:\[(?P<codes>[A-Z0-9,\s]+)\])?"
    r"(?:\s*(?:--|—|:)\s*(?P<reason>\S.*))?")

# paths never linted: seeded-violation fixtures + VCS/venv noise
EXCLUDED_PARTS = {"fixtures", ".git", ".venv", "__pycache__",
                  "node_modules", ".claude"}


def _dotted(node: ast.AST) -> str:
    """'jax.random.PRNGKey' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        return ""
    return ".".join(reversed(parts))


def _tail(node: ast.AST) -> str:
    d = _dotted(node)
    return d.rsplit(".", 1)[-1] if d else ""


class _Waivers:
    def __init__(self, source: str):
        self.by_line = {}
        self.findings: List[Finding] = []
        lines = source.splitlines()
        for i, line in enumerate(lines, start=1):
            m = _WAIVER_RE.search(line)
            if not m:
                continue
            codes = frozenset(
                c.strip() for c in (m.group("codes") or "").split(",")
                if c.strip()) or None           # None = waive any code
            self._add(i, codes)
            if line.lstrip().startswith("#"):
                # standalone waiver comment (possibly a multi-line block):
                # it covers the first CODE line below it
                j = i
                while j < len(lines) and \
                        (not lines[j].strip()
                         or lines[j].lstrip().startswith("#")):
                    j += 1
                self._add(j + 1, codes)
            if not (m.group("reason") or "").strip():
                self.findings.append(Finding(
                    "RPR000", f"{{path}}:{i}",
                    "waiver without a reason — write "
                    "`# rpr: ignore[CODE] -- why this hit is intended`"))

    def _add(self, line: int, codes):
        prev = self.by_line.get(line, frozenset())
        if codes is None or prev is None:
            self.by_line[line] = None
        else:
            self.by_line[line] = prev | codes

    def covers(self, line: int, code: str) -> bool:
        codes = self.by_line.get(line, False)
        return codes is not False and (codes is None or code in codes)


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.findings: List[Finding] = []
        self.waivers = _Waivers(source)
        self.loop_depth = 0
        # per-function: does it drive jitted calls (RPR004 scope)?
        self._fn_stack: List[bool] = []

    # ------------------------------------------------------------- helpers
    def _emit(self, code: str, node: ast.AST, msg: str):
        line = getattr(node, "lineno", 0)
        if self.waivers.covers(line, code):
            return
        self.findings.append(Finding(code, f"{self.path}:{line}", msg))

    def _names_in(self, node: ast.AST) -> Iterable[str]:
        for n in ast.walk(node):
            if isinstance(n, ast.Name):
                yield n.id
            elif isinstance(n, ast.Attribute):
                yield n.attr

    # --------------------------------------------------------------- scopes
    def _visit_fn(self, node):
        drives_jit = False
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                t = _tail(n.func)
                if t.endswith("_jit") or t == "jit":
                    drives_jit = True
                    break
        self._fn_stack.append(drives_jit)
        saved, self.loop_depth = self.loop_depth, 0
        self.generic_visit(node)
        self.loop_depth = saved
        self._fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _visit_loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop

    # ---------------------------------------------------------------- rules
    def visit_Call(self, node: ast.Call):
        self._rule_prngkey(node)
        self._rule_subprocess_env(node)
        self._rule_host_roundtrip(node)
        self._rule_undonated_jit(node)
        self.generic_visit(node)

    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        self._rule_swallowed_except(node)
        self.generic_visit(node)

    # RPR001 ---------------------------------------------------------------
    def _rule_prngkey(self, node: ast.Call):
        if _tail(node.func) != "PRNGKey":
            return
        if self.loop_depth > 0:
            self._emit("RPR001", node,
                       "PRNGKey inside a loop — the same (or a correlated "
                       "loop-index) key every iteration; fold_in a step "
                       "counter from ONE base key instead")
            return
        for arg in node.args:
            attrs = [n.attr for n in ast.walk(arg)
                     if isinstance(n, ast.Attribute)]
            if attrs and not any(SEEDISH.search(a) for a in attrs):
                self._emit("RPR001", node,
                           f"PRNGKey({ast.unparse(arg)}) keys off mutable "
                           "state — a counter revisits values across "
                           "call sites (the PR 1 sampler collision); "
                           "fold_in the counter from a seed-derived base")

    # RPR002 ---------------------------------------------------------------
    def _rule_subprocess_env(self, node: ast.Call):
        d = _dotted(node.func)
        if not (d.startswith("subprocess.") and
                d.rsplit(".", 1)[-1] in SUBPROCESS_CALLS):
            return
        env_kw = next((k for k in node.keywords if k.arg == "env"), None)
        if env_kw is None:
            return                      # inherits the parent env: fine
        v = env_kw.value
        keys: List[Optional[str]] = []
        spreads_environ = False
        if isinstance(v, ast.Dict):
            for k in v.keys:
                if k is None:           # {**something}
                    spreads_environ = True
                elif isinstance(k, ast.Constant):
                    keys.append(str(k.value))
        elif isinstance(v, ast.Call) and _tail(v.func) == "dict":
            for kw in v.keywords:
                if kw.arg is None:
                    spreads_environ = True
                else:
                    keys.append(kw.arg)
        else:
            return                      # built elsewhere: not analyzable
        if spreads_environ or "JAX_PLATFORMS" in keys:
            return
        self._emit("RPR002", node,
                   "subprocess env dict drops JAX_PLATFORMS — the child "
                   "jax probes accelerator plugins and can hang (PR 1); "
                   "spread **os.environ or set JAX_PLATFORMS explicitly")

    # RPR003 ---------------------------------------------------------------
    def _rule_swallowed_except(self, node: ast.ExceptHandler):
        broad = node.type is None or _tail(node.type) in (
            "Exception", "BaseException")
        if not broad:
            return
        # a pure re-raise handler propagates the fault — nothing swallowed
        if len(node.body) == 1 and isinstance(node.body[0], ast.Raise) \
                and node.body[0].exc is None:
            return
        if node.name is None:
            self._emit("RPR003", node,
                       "broad except without binding the exception — the "
                       "fault's type/message cannot reach any log "
                       "(applied/reason, monitor events); bind `as e` "
                       "and record it")
            return
        used = any(isinstance(n, ast.Name) and n.id == node.name
                   for stmt in node.body for n in ast.walk(stmt))
        if not used:
            self._emit("RPR003", node,
                       f"broad except binds `{node.name}` but never uses "
                       "it — record the exception type/message before "
                       "continuing")

    # RPR004 ---------------------------------------------------------------
    def _rule_host_roundtrip(self, node: ast.Call):
        if self.loop_depth == 0 or not (self._fn_stack and
                                        self._fn_stack[-1]):
            return
        t = _tail(node.func)
        hit = None
        if t == "item" and isinstance(node.func, ast.Attribute):
            hit = ".item()"
        elif _dotted(node.func) in ("np.asarray", "numpy.asarray",
                                    "onp.asarray"):
            hit = "np.asarray"
        elif isinstance(node.func, ast.Name) and t in HOST_ROUNDTRIP_NAMES:
            # float()/int() of a literal or len() is host-side anyway;
            # flag conversions of computed/indexed values only
            if node.args and not isinstance(node.args[0], ast.Constant) \
                    and not (isinstance(node.args[0], ast.Call)
                             and _tail(node.args[0].func) == "len"):
                hit = f"{t}()"
        if hit:
            self._emit("RPR004", node,
                       f"{hit} inside the stepping loop of a jit-driving "
                       "function — a host device-sync per iteration; "
                       "keep the value on device or hoist the transfer "
                       "out of the loop")

    # RPR005 ---------------------------------------------------------------
    def _rule_undonated_jit(self, node: ast.Call):
        if _dotted(node.func) not in ("jax.jit", "jit"):
            return
        if not node.args:
            return
        target = _tail(node.args[0])
        if target not in STATEFUL_JIT_TARGETS:
            return
        kws = {k.arg for k in node.keywords}
        if kws & {"donate_argnums", "donate_argnames"}:
            return
        self._emit("RPR005", node,
                   f"jax.jit({ast.unparse(node.args[0])}) carries decode/"
                   "cache state but donates nothing — every call "
                   "materializes a second full KV cache; pass "
                   "donate_argnums for the state argument")


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("RPR999", f"{path}:{e.lineno}",
                        f"syntax error stops linting: {e.msg}")]
    linter = _FileLinter(path, source)
    linter.visit(tree)
    out = linter.findings + [
        Finding(f.code, f.where.format(path=path), f.message)
        for f in linter.waivers.findings]
    return sorted(out, key=lambda f: (f.where, f.code))


def iter_python_files(roots: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file() and p.suffix == ".py":
            files.append(p)
            continue
        for f in sorted(p.rglob("*.py")):
            if not EXCLUDED_PARTS.intersection(f.parts):
                files.append(f)
    return files


def lint_paths(roots: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(roots):
        findings.extend(lint_source(f.read_text(), str(f)))
    return findings
