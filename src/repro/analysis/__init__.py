"""Hot-path auditor: static analysis for the decode loop + repo lints.

The paper's premise is serving under tight edge memory/latency budgets,
so this package makes the *compiled* cost of the serving hot path a
checked artifact (the same fail-closed philosophy as
``benchmarks/run.py --check``):

``jaxpr_audit``
    Abstractly traces the engine's jitted hot functions (``decode_step``,
    ``prefill_bucketed``, ``insert_slot``, the resident-kernel dispatch)
    and walks the jaxprs for implicit dtype promotions on cache-sized
    arrays, host callbacks inside jit, and large closure-captured
    constants (retrace / bake-in hazards).

``hlo_audit``
    Reuses and extends ``repro.launch.hlo_analysis`` on the OPTIMIZED
    decode HLO: donation failures (cache-sized outputs that are not
    input/output-aliased, full-cache copies of parameters), a
    recompile-ladder census over the prefill buckets, and op/byte budgets
    against the committed ``baselines.json``.

``lints``
    Standalone AST lints (RPR0xx codes, no jax import) encoding the bug
    classes previous PRs fixed by hand: PRNGKey reuse / loop-counter
    keys, ``subprocess`` env dicts that drop ``JAX_PLATFORMS``, swallowed
    broad ``except`` handlers, host round-trips inside jit-stepping
    loops, and ``jax.jit`` of state-carrying signatures without
    ``donate_argnums``.  Waive a true-but-intended hit inline with
    ``# rpr: ignore[CODE] -- reason``.

CLI: ``python -m repro.analysis [lint|jaxpr|hlo ...]`` — exits non-zero
on any unwaived finding; wired into ``scripts/ci.sh`` and the GitHub
workflow as a failing gate.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One auditor hit.  ``code`` families: RPR0xx (AST lints), JXP0xx
    (jaxpr audit), HLO0xx (compiled-HLO audit)."""
    code: str
    where: str            # "path:line" or "function/op" locator
    message: str

    def __str__(self) -> str:
        return f"{self.code} {self.where}: {self.message}"


__all__ = ["Finding"]
