"""CLI for the hot-path auditor.

    PYTHONPATH=src python -m repro.analysis [pass ...] [options]

Passes (default: all three):
    lint    repo-invariant RPR0xx AST lints (stdlib-only, no jax)
    jaxpr   abstract-trace audit of the jitted hot functions (JXP0xx)
    hlo     optimized-HLO audit of the compiled decode path (HLO0xx)

Options:
    --paths P [P ...]     lint roots (default: src benchmarks examples
                          tests scripts)
    --update-baselines    refresh src/repro/analysis/baselines.json from
                          the current build, then exit 0
    --json                machine-readable findings on stdout

Exit status: 0 when clean, 1 on any unwaived finding — wired into
scripts/ci.sh (after ruff, before pytest) and the ci.yml audit job.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

DEFAULT_LINT_PATHS = ["src", "benchmarks", "examples", "tests", "scripts"]
PASSES = ("lint", "jaxpr", "hlo")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Hot-path auditor: jaxpr/HLO static analysis for the "
                    "decode loop + repo-invariant lints")
    # no argparse `choices`: its empty-default validation bug rejects the
    # zero-arg (run everything) form on some 3.x versions
    ap.add_argument("passes", nargs="*", metavar="{lint,jaxpr,hlo}",
                    help="subset of passes to run (default: all)")
    ap.add_argument("--paths", nargs="+", default=DEFAULT_LINT_PATHS)
    ap.add_argument("--update-baselines", action="store_true")
    ap.add_argument("--json", action="store_true", dest="as_json")
    args = ap.parse_args(argv)
    bad = set(args.passes) - set(PASSES)
    if bad:
        ap.error(f"unknown pass(es) {sorted(bad)} — choose from {PASSES}")
    passes = tuple(args.passes) or PASSES

    if args.update_baselines:
        from repro.analysis.hlo_audit import BASELINES_PATH, update_baselines
        vals = update_baselines()
        print(f"[analysis] wrote {BASELINES_PATH}:")
        for k, v in sorted(vals.items()):
            print(f"    {k} = {v:g}")
        return 0

    findings = []
    for name in PASSES:           # fixed order: cheap/standalone first
        if name not in passes:
            continue
        t0 = time.monotonic()
        if name == "lint":
            from repro.analysis.lints import lint_paths
            found = lint_paths(args.paths)
        elif name == "jaxpr":
            from repro.analysis.jaxpr_audit import audit_hot_functions
            found = audit_hot_functions()
        else:
            from repro.analysis.hlo_audit import audit_compiled_hot_path
            found = audit_compiled_hot_path()
        dt = time.monotonic() - t0
        if not args.as_json:
            state = "clean" if not found else f"{len(found)} finding(s)"
            print(f"[analysis] {name}: {state} ({dt:.1f}s)")
        findings.extend(found)

    if args.as_json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f"  {f}")
        if findings:
            print(f"[analysis] FAILED: {len(findings)} unwaived finding(s)"
                  " — fix, or waive inline with `# rpr: ignore[CODE] -- "
                  "reason` (lints) / refresh budgets (hlo)")
        else:
            print("[analysis] hot path audits clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
