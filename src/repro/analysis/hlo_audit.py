"""Optimized-HLO audit of the compiled decode hot path (HLO0xx).

Builds the REAL serving engine (tiny dense config, CPU) so the audited
artifacts are the engine's own jit wrappers — ``_decode_jit``,
``_prefill_bucketed_jit``, ``_insert_jit`` — not look-alikes, then:

HLO001  donation failure: a cache-sized ENTRY output with no
        ``input_output_alias`` entry.  Without the alias the decode step
        materializes a second full KV cache per call — the exact
        per-device memory Algorithm 1 partitions.
HLO002  full-cache copy-on-write: a ``copy`` op of at least cache size
        whose operand chains back to a parameter — the input cache is
        being duplicated instead of updated via in-place
        ``dynamic-update-slice``.
HLO003  recompile ladder: more distinct prefill lowerings than buckets
        (or than the committed budget) — every extra lowering is an
        unattributed multi-second stall in the serving loop.
HLO004  op/byte budget: trip-multiplied ``dot_flops`` / ``hbm_bytes``
        (``launch.hlo_analysis.full_analysis``) and collective-op counts
        of the decode step drifted past ``baselines.json`` — the same
        fail-closed philosophy as ``benchmarks/run.py --check``: a
        missing baseline key fails with the refresh command instead of
        silently passing.

Refresh budgets after an intended change:
``python -m repro.analysis hlo --update-baselines``.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.analysis import Finding
from repro.launch import hlo_analysis as H

BASELINES_PATH = Path(__file__).with_name("baselines.json")
REFRESH_CMD = ("PYTHONPATH=src python -m repro.analysis hlo "
               "--update-baselines")
# relative headroom before a drift fails: flops are deterministic given
# the model; hbm bytes move a little across XLA releases
TOLERANCES = {"dot_flops": 0.10, "hbm_bytes": 0.30, "collective_ops": 0.0,
              "prefill_lowerings": 0.0, "full_cache_param_copies": 0.0}

AUDIT_BUCKETS = (8, 16, 32, 64)
_CACHE = {}


def build_audit_setup() -> dict:
    """The shared audit fixture: a tiny dense model + the abstract decode/
    prefill/insert arguments (memoized — jaxpr and HLO passes share it)."""
    if "setup" in _CACHE:
        return _CACHE["setup"]
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.serving.engine import ServingEngine

    cfg = ModelConfig(
        name="audit-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256,
        rope_theta=10_000.0, norm_eps=1e-5)
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=16, seed=0,
                        buckets=AUDIT_BUCKETS)
    m, params, state = eng.model, eng.params, eng.state
    Lb = AUDIT_BUCKETS[1]
    sub = m.init_decode_state(params, 1, Lb, per_slot=True)
    setup = {
        "cfg": cfg, "engine": eng, "model": m, "params": params,
        "state": state, "tokens": jnp.zeros((2,), jnp.int32),
        "buckets": AUDIT_BUCKETS,
        "bucket_state": sub,
        "bucket_tokens": jnp.zeros((1, Lb), jnp.int32),
        "bucket_lengths": jnp.asarray([Lb // 2], jnp.int32),
        "sub_state": m.init_decode_state(params, 1, Lb, per_slot=True),
    }
    _CACHE["setup"] = setup
    return setup


def build_paged_audit_setup() -> dict:
    """Paged twin of :func:`build_audit_setup`: the SAME audit-tiny config
    served through the paged engine (page_size 8), so the paged decode /
    chunked-prefill / page-mount jits are audited as the engine builds
    them (memoized)."""
    if "paged_setup" in _CACHE:
        return _CACHE["paged_setup"]
    import jax.numpy as jnp
    from repro.serving.engine import ServingEngine

    cfg = build_audit_setup()["cfg"]
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=16, seed=0,
                        paged=True, page_size=8)
    setup = {
        "cfg": cfg, "engine": eng, "model": eng.model,
        "params": eng.params, "state": eng.state,
        "tokens": jnp.zeros((2,), jnp.int32),
        "chunk_tokens": jnp.zeros((1, eng.prefill_chunk), jnp.int32),
        "page_row": jnp.zeros((eng.pages_per_slot,), jnp.int32),
    }
    _CACHE["paged_setup"] = setup
    return setup


def build_moe_audit_setup() -> dict:
    """MoE twin of :func:`build_audit_setup`: a reduced mixtral (4 experts,
    top-2, physical owner/share expert layout installed by the engine) so
    the expert decode path — router, one-hot physical combine, expert-load
    EWMA — sits under the same donation/copy/lowering budgets as the dense
    and paged paths (memoized)."""
    if "moe_setup" in _CACHE:
        return _CACHE["moe_setup"]
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.serving.engine import ServingEngine

    cfg = get_config("mixtral-8x7b").with_overrides(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, n_experts=4, sliding_window=128,
        dtype="float32", param_dtype="float32")
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=16, seed=0,
                        buckets=AUDIT_BUCKETS)
    setup = {
        "cfg": cfg, "engine": eng, "model": eng.model,
        "params": eng.params, "state": eng.state,
        "tokens": jnp.zeros((2,), jnp.int32),
        "buckets": AUDIT_BUCKETS,
    }
    _CACHE["moe_setup"] = setup
    return setup


def cache_bytes_of(state) -> int:
    k = state["cache"]["k"]
    return int(k.size) * int(np.dtype(k.dtype).itemsize)


def decode_hlo_text() -> str:
    """Optimized HLO of the engine's OWN decode jit wrapper."""
    if "decode_hlo" not in _CACHE:
        s = build_audit_setup()
        eng = s["engine"]
        _CACHE["decode_hlo"] = eng._decode_jit.lower(
            s["params"], s["state"], s["tokens"]).compile().as_text()
    return _CACHE["decode_hlo"]


def paged_decode_hlo_text() -> str:
    """Optimized HLO of the paged engine's decode jit (page-gather path)."""
    if "paged_decode_hlo" not in _CACHE:
        s = build_paged_audit_setup()
        _CACHE["paged_decode_hlo"] = s["engine"]._decode_jit.lower(
            s["params"], s["state"], s["tokens"]).compile().as_text()
    return _CACHE["paged_decode_hlo"]


def moe_decode_hlo_text() -> str:
    """Optimized HLO of the MoE engine's decode jit (expert combine path)."""
    if "moe_decode_hlo" not in _CACHE:
        s = build_moe_audit_setup()
        _CACHE["moe_decode_hlo"] = s["engine"]._decode_jit.lower(
            s["params"], s["state"], s["tokens"]).compile().as_text()
    return _CACHE["moe_decode_hlo"]


def audit_decode_hlo(hlo_text: str, cache_bytes: int,
                     where: str = "decode_step") -> List[Finding]:
    """HLO001/HLO002 on one optimized module (pure text, testable on
    committed fixtures)."""
    findings: List[Finding] = []
    aliases = H.input_output_aliases(hlo_text)
    aliased_idx = {p[0] for p in aliases if len(p) >= 1}
    outs = H.entry_output_shapes(hlo_text)
    for i, (dtype, dims, nbytes) in enumerate(outs):
        if nbytes >= cache_bytes and i not in aliased_idx:
            findings.append(Finding(
                "HLO001", f"{where}/output[{i}]",
                f"cache-sized output {dtype}[{dims}] ({nbytes} B) is not "
                f"input/output-aliased — the jit does not donate the "
                f"state, so every decode step allocates a second full KV "
                f"cache; pass donate_argnums for the state argument"))
    for c in H.find_copy_ops(hlo_text, min_bytes=cache_bytes):
        if c["from_parameter"]:
            findings.append(Finding(
                "HLO002", f"{where}/{c['computation']}/{c['name']}",
                f"full-cache copy ({c['bytes']} B) of parameter-derived "
                f"`{c['operand']}` — the input cache is duplicated "
                f"instead of updated in place via dynamic-update-slice"))
    return findings


def prefill_ladder() -> Dict[str, int]:
    """Distinct prefill lowerings across the engine's bucket set (the
    compile ladder a serving process pays once per bucket — and must not
    pay per prompt length)."""
    if "ladder" in _CACHE:
        return _CACHE["ladder"]
    import jax.numpy as jnp
    s = build_audit_setup()
    eng, m, params = s["engine"], s["model"], s["params"]
    seen = set()
    for Lb in s["buckets"]:
        sub = m.init_decode_state(params, 1, Lb, per_slot=True)
        low = eng._prefill_bucketed_jit.lower(
            params, sub, jnp.zeros((1, Lb), jnp.int32),
            jnp.asarray([Lb // 2], jnp.int32))
        seen.add(hash(low.as_text()))
    # insert_slot must be ONE lowering for every slot index (traced slot)
    low_a = eng._insert_jit.lower(s["state"], s["sub_state"], jnp.int32(0))
    low_b = eng._insert_jit.lower(s["state"], s["sub_state"], jnp.int32(1))
    insert_lowerings = len({hash(low_a.as_text()), hash(low_b.as_text())})
    _CACHE["ladder"] = {"prefill_lowerings": len(seen),
                        "n_buckets": len(s["buckets"]),
                        "insert_lowerings": insert_lowerings}
    return _CACHE["ladder"]


def paged_ladder() -> Dict[str, int]:
    """Chunked prefill must be ONE lowering for every (row, start, length)
    — the whole point of splicing prompts page-by-page through a fixed
    chunk shape — and the page-table mount ONE lowering for every row."""
    if "paged_ladder" in _CACHE:
        return _CACHE["paged_ladder"]
    import jax.numpy as jnp
    s = build_paged_audit_setup()
    eng = s["engine"]
    seen = set()
    for row, start, length in ((0, 0, 3), (1, 8, 8), (0, 16, 1)):
        low = eng._paged_prefill_jit.lower(
            s["params"], s["state"], s["chunk_tokens"], jnp.int32(row),
            jnp.int32(start), jnp.int32(length))
        seen.add(hash(low.as_text()))
    mounts = set()
    for row in (0, 1):
        low = eng._mount_jit.lower(s["state"], jnp.int32(row),
                                   s["page_row"], jnp.int32(0))
        mounts.add(hash(low.as_text()))
    _CACHE["paged_ladder"] = {"prefill_lowerings": len(seen),
                              "mount_lowerings": len(mounts)}
    return _CACHE["paged_ladder"]


def measure() -> Dict[str, float]:
    """The budget-able numbers of the current build."""
    s = build_audit_setup()
    txt = decode_hlo_text()
    full = H.full_analysis(txt)
    coll = H.collective_bytes(txt)
    ladder = prefill_ladder()
    n_coll = sum(coll["_counts"].values()) if "_counts" in coll else 0
    cbytes = cache_bytes_of(s["state"])
    param_copies = sum(1 for c in H.find_copy_ops(txt, min_bytes=cbytes)
                      if c["from_parameter"])
    return {
        "dot_flops": float(full["dot_flops"]),
        "hbm_bytes": float(full["hbm_bytes"]),
        "collective_ops": float(n_coll),
        "prefill_lowerings": float(ladder["prefill_lowerings"]),
        "insert_lowerings": float(ladder["insert_lowerings"]),
        "full_cache_param_copies": float(param_copies),
        "aliased_outputs": float(len(H.input_output_aliases(txt))),
    }


def measure_paged() -> Dict[str, float]:
    """Budget-able numbers for the paged decode hot path (same keys as
    :func:`measure`, page-gather decode + chunked prefill + mount)."""
    s = build_paged_audit_setup()
    txt = paged_decode_hlo_text()
    full = H.full_analysis(txt)
    coll = H.collective_bytes(txt)
    ladder = paged_ladder()
    n_coll = sum(coll["_counts"].values()) if "_counts" in coll else 0
    cbytes = cache_bytes_of(s["state"])
    param_copies = sum(1 for c in H.find_copy_ops(txt, min_bytes=cbytes)
                       if c["from_parameter"])
    return {
        "dot_flops": float(full["dot_flops"]),
        "hbm_bytes": float(full["hbm_bytes"]),
        "collective_ops": float(n_coll),
        "prefill_lowerings": float(ladder["prefill_lowerings"]),
        "insert_lowerings": float(ladder["mount_lowerings"]),
        "full_cache_param_copies": float(param_copies),
        "aliased_outputs": float(len(H.input_output_aliases(txt))),
    }


def moe_ladder() -> Dict[str, int]:
    """Prefill/insert compile ladders of the MoE engine (the bucket set
    must bound the prefill lowerings exactly as on the dense path — the
    router adds ops, not shapes)."""
    if "moe_ladder" in _CACHE:
        return _CACHE["moe_ladder"]
    import jax.numpy as jnp
    s = build_moe_audit_setup()
    eng, m, params = s["engine"], s["model"], s["params"]
    seen = set()
    for Lb in s["buckets"]:
        sub = m.init_decode_state(params, 1, Lb, per_slot=True)
        low = eng._prefill_bucketed_jit.lower(
            params, sub, jnp.zeros((1, Lb), jnp.int32),
            jnp.asarray([Lb // 2], jnp.int32))
        seen.add(hash(low.as_text()))
    sub = m.init_decode_state(params, 1, AUDIT_BUCKETS[1], per_slot=True)
    low_a = eng._insert_jit.lower(s["state"], sub, jnp.int32(0))
    low_b = eng._insert_jit.lower(s["state"], sub, jnp.int32(1))
    insert_lowerings = len({hash(low_a.as_text()), hash(low_b.as_text())})
    _CACHE["moe_ladder"] = {"prefill_lowerings": len(seen),
                            "n_buckets": len(s["buckets"]),
                            "insert_lowerings": insert_lowerings}
    return _CACHE["moe_ladder"]


def measure_moe() -> Dict[str, float]:
    """Budget-able numbers for the MoE decode hot path (same keys as
    :func:`measure`: router + expert einsums + one-hot combine under the
    same donation/copy/flops/bytes budgets)."""
    s = build_moe_audit_setup()
    txt = moe_decode_hlo_text()
    full = H.full_analysis(txt)
    coll = H.collective_bytes(txt)
    ladder = moe_ladder()
    n_coll = sum(coll["_counts"].values()) if "_counts" in coll else 0
    cbytes = cache_bytes_of(s["state"])
    param_copies = sum(1 for c in H.find_copy_ops(txt, min_bytes=cbytes)
                       if c["from_parameter"])
    return {
        "dot_flops": float(full["dot_flops"]),
        "hbm_bytes": float(full["hbm_bytes"]),
        "collective_ops": float(n_coll),
        "prefill_lowerings": float(ladder["prefill_lowerings"]),
        "insert_lowerings": float(ladder["insert_lowerings"]),
        "full_cache_param_copies": float(param_copies),
        "aliased_outputs": float(len(H.input_output_aliases(txt))),
    }


def update_baselines(path: Path = BASELINES_PATH) -> Dict[str, float]:
    vals = measure()
    payload = {
        "_meta": {
            "model": "audit-tiny (2L, d64, 4h, B2, T64)",
            "refresh": REFRESH_CMD,
            "note": "budgets for the compiled decode hot path; counts "
                    "gate exactly, flops/bytes gate at TOLERANCES",
        },
        "decode_step": vals,
        "paged_decode_step": measure_paged(),
        "moe_decode_step": measure_moe(),
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return vals


def audit_budgets(path: Path = BASELINES_PATH) -> List[Finding]:
    """HLO004: fail-closed comparison against the committed budget."""
    if not path.exists():
        return [Finding("HLO004", str(path),
                        f"budget file missing — the gate cannot pass "
                        f"without one; run `{REFRESH_CMD}`")]
    doc = json.loads(path.read_text())
    findings: List[Finding] = []
    for section, vals in (("decode_step", measure()),
                          ("paged_decode_step", measure_paged()),
                          ("moe_decode_step", measure_moe())):
        base = doc.get(section, {})
        for key, tol in TOLERANCES.items():
            if key not in base:
                findings.append(Finding(
                    "HLO004", f"baselines.json/{section}/{key}",
                    f"no committed budget for `{key}` (fresh value "
                    f"{vals[key]:g}) — fail-closed; run `{REFRESH_CMD}`"))
                continue
            b, v = float(base[key]), float(vals[key])
            limit = b * (1.0 + tol) if b > 0 else b
            if v > limit:
                findings.append(Finding(
                    "HLO004", f"{section}/{key}",
                    f"{key} regressed: {v:g} > budget {b:g} (+{tol:.0%} "
                    f"headroom) — an unpriced cost crept into the decode "
                    f"hot path; fix it or refresh via `{REFRESH_CMD}`"))
    return findings


def audit_compiled_hot_path() -> List[Finding]:
    """All HLO passes on the live build."""
    s = build_audit_setup()
    findings = audit_decode_hlo(decode_hlo_text(),
                                cache_bytes_of(s["state"]))
    ladder = prefill_ladder()
    if ladder["prefill_lowerings"] > ladder["n_buckets"]:
        findings.append(Finding(
            "HLO003", "prefill_bucketed",
            f"{ladder['prefill_lowerings']} distinct prefill lowerings "
            f"for {ladder['n_buckets']} buckets — the bucket set no "
            f"longer bounds the compile ladder"))
    if ladder["insert_lowerings"] != 1:
        findings.append(Finding(
            "HLO003", "insert_slot",
            f"insert_slot lowers {ladder['insert_lowerings']} times for "
            f"two slot indices — the slot must stay a traced scalar so "
            f"one compile serves every slot"))
    ps = build_paged_audit_setup()
    findings.extend(audit_decode_hlo(paged_decode_hlo_text(),
                                     cache_bytes_of(ps["state"]),
                                     where="paged_decode_step"))
    pl = paged_ladder()
    if pl["prefill_lowerings"] != 1:
        findings.append(Finding(
            "HLO003", "prefill_paged",
            f"chunked prefill lowers {pl['prefill_lowerings']} times "
            f"across (row, start, length) variations — the chunk shape "
            f"is fixed and all placement scalars must stay traced so "
            f"ONE compile splices every prompt"))
    if pl["mount_lowerings"] != 1:
        findings.append(Finding(
            "HLO003", "mount_slot_pages",
            f"page-table mount lowers {pl['mount_lowerings']} times for "
            f"two rows — the row must stay a traced scalar"))
    ms = build_moe_audit_setup()
    findings.extend(audit_decode_hlo(moe_decode_hlo_text(),
                                     cache_bytes_of(ms["state"]),
                                     where="moe_decode_step"))
    ml = moe_ladder()
    if ml["prefill_lowerings"] > ml["n_buckets"]:
        findings.append(Finding(
            "HLO003", "moe/prefill_bucketed",
            f"{ml['prefill_lowerings']} distinct MoE prefill lowerings "
            f"for {ml['n_buckets']} buckets — the bucket set no longer "
            f"bounds the compile ladder on the expert path"))
    if ml["insert_lowerings"] != 1:
        findings.append(Finding(
            "HLO003", "moe/insert_slot",
            f"MoE insert_slot lowers {ml['insert_lowerings']} times for "
            f"two slot indices — the slot must stay a traced scalar"))
    findings.extend(audit_budgets())
    return findings
