"""Jaxpr audit of the serving hot path (JXP0xx findings).

Abstractly traces the engine's jitted hot functions — ``decode_step``,
``prefill_bucketed``, ``insert_slot`` and the resident-kernel dispatch —
with ``jax.make_jaxpr`` (no compile, no execution) and walks every eqn,
recursing into scan/while/cond/pjit/pallas sub-jaxprs:

JXP001  implicit dtype promotion on a cache-sized array: a
        ``convert_element_type`` that WIDENS an operand of at least
        ``big_elems`` elements.  A widened KV cache is the exact memory
        Algorithm 1 budgets — a stray f32 upcast of a bf16/int8 cache
        doubles (quadruples) the per-device resident bytes.
JXP002  host callback / transfer primitive inside the jitted body
        (``pure_callback``/``io_callback``/``debug_callback``/ ...): a
        hidden host sync per decode step that no bench row attributes.
JXP003  large closure-captured constant: a concrete array baked into the
        jaxpr consts.  Bakes weights into the executable (doubling their
        footprint) and retraces whenever the enclosing closure is
        rebuilt — the recompile-ladder seed.

``audit_hot_functions()`` builds the shared tiny audit model
(``hlo_audit.build_audit_setup``) and runs all hot functions through
``audit_jaxpr``.
"""
from __future__ import annotations

from typing import Any, Iterable, List, Tuple

import jax
import numpy as np

from repro.analysis import Finding

HOST_CALLBACK_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
}
# benign converts: iota/bool masks and scalar bookkeeping promote freely
DEFAULT_BIG_ELEMS = 8192


def _sub_jaxprs(params: dict) -> Iterable[Any]:
    """Every Jaxpr/ClosedJaxpr reachable from an eqn's params (scan/while
    bodies, cond branches, pjit/pallas_call callees, custom_* rules)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if hasattr(item, "jaxpr") and hasattr(item, "consts"):
                yield item.jaxpr          # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item                # raw Jaxpr


def _iter_eqns(jaxpr) -> Iterable[Tuple[Any, Any]]:
    """(eqn, owning jaxpr) pairs, depth-first over sub-jaxprs."""
    seen = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        for eqn in j.eqns:
            yield eqn, j
            stack.extend(_sub_jaxprs(eqn.params))


def _aval(var):
    return getattr(var, "aval", None)


def audit_jaxpr(closed_jaxpr, name: str, *,
                big_elems: int = DEFAULT_BIG_ELEMS) -> List[Finding]:
    """Walk one ClosedJaxpr for the three hazard classes."""
    findings: List[Finding] = []
    jaxpr = closed_jaxpr.jaxpr

    # JXP003: top-level consts are the closure captures (sub-jaxpr consts
    # are threaded as constvars and surface here too)
    for const in closed_jaxpr.consts:
        arr = np.asarray(const) if hasattr(const, "shape") else None
        if arr is not None and arr.size >= big_elems:
            findings.append(Finding(
                "JXP003", f"{name}/consts",
                f"closure-captured constant {arr.dtype}{list(arr.shape)} "
                f"({arr.size} elems) baked into the jaxpr — doubles its "
                f"footprint in the executable and forces a retrace when "
                f"the closure is rebuilt; pass it as an argument"))

    for eqn, _ in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim == "convert_element_type":
            inv = _aval(eqn.invars[0])
            outv = _aval(eqn.outvars[0])
            if inv is None or outv is None:
                continue
            size = int(np.prod(inv.shape)) if inv.shape else 1
            if size < big_elems:
                continue
            try:
                widen = (np.dtype(outv.dtype).itemsize
                         > np.dtype(inv.dtype).itemsize)
            except TypeError:
                widen = False
            if widen:
                findings.append(Finding(
                    "JXP001", f"{name}/{prim}",
                    f"implicit promotion {inv.dtype}{list(inv.shape)} -> "
                    f"{outv.dtype} on a cache-sized array ({size} elems): "
                    f"a widened resident buffer is exactly the memory the "
                    f"placement algorithm budgets — cast the small "
                    f"operand down instead"))
        elif prim in HOST_CALLBACK_PRIMITIVES:
            findings.append(Finding(
                "JXP002", f"{name}/{prim}",
                f"host callback `{prim}` inside the jitted hot function — "
                f"a host round-trip per decode step that no bench row "
                f"attributes; move it outside jit or behind a debug flag"))
    return findings


def audit_hot_functions(*, big_elems: int = None) -> List[Finding]:
    """Trace the four serving hot functions on the shared audit model."""
    from repro.analysis.hlo_audit import build_audit_setup
    from repro.kernels.decode_attention import decode_attention_resident

    setup = build_audit_setup()
    m, params, state, toks = (setup["model"], setup["params"],
                              setup["state"], setup["tokens"])
    cache_k = state["cache"]["k"]
    # "cache-sized" for THIS model: one full layer of KV rows
    big = big_elems or max(int(np.prod(cache_k.shape[1:])) // 2, 1024)

    findings: List[Finding] = []
    findings += audit_jaxpr(
        jax.make_jaxpr(m.decode_step)(params, state, toks),
        "decode_step", big_elems=big)
    findings += audit_jaxpr(
        jax.make_jaxpr(m.prefill_bucketed)(
            params, setup["bucket_state"], setup["bucket_tokens"],
            setup["bucket_lengths"]),
        "prefill_bucketed", big_elems=big)
    findings += audit_jaxpr(
        jax.make_jaxpr(m.insert_slot)(state, setup["sub_state"],
                                      np.int32(0)),
        "insert_slot", big_elems=big)
    # resident-kernel dispatch: identity gather map over all heads
    B, T = cache_k.shape[1], cache_k.shape[2]
    KvE, dh = cache_k.shape[3], cache_k.shape[4]
    H = setup["cfg"].n_heads
    q = jax.ShapeDtypeStruct((B, H, dh), cache_k.dtype)
    kv = jax.ShapeDtypeStruct((B, KvE, T, dh), cache_k.dtype)
    lengths = jax.ShapeDtypeStruct((B,), np.int32)
    rows = jax.ShapeDtypeStruct((H,), np.int32)
    findings += audit_jaxpr(
        jax.make_jaxpr(
            lambda q, k, v, ln, r: decode_attention_resident(
                q, k, v, ln, r, interpret=True))(q, kv, kv, lengths, rows),
        "decode_attention_resident", big_elems=big)
    return findings
