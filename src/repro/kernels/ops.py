"""Public jit'd wrappers for the Pallas kernels, in model-layout
((B, S, H, dh)) with shape checks and automatic interpret-mode on CPU.

These are the TPU hot paths the model code dispatches to when
``use_kernel=True``; the pure-jnp paths in the model modules remain the
oracles (kernels/ref.py mirrors them in kernel layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (
    decode_attention as _decode,
    decode_attention_int8_paged_resident as _decode_i8_paged,
    decode_attention_int8_resident as _decode_i8_res,
    decode_attention_paged_resident as _decode_paged,
    decode_attention_resident as _decode_res,
    decode_attention_ring_resident as _decode_ring,
)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rwkv6_kernel import rwkv6_chunked as _rwkv6


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention_bshd(q, k, v, *, causal: bool = True, window: int = 0,
                         interpret: bool | None = None):
    """Model layout: q (B,S,H,dh), k/v (B,T,KvE,dh) -> (B,S,H,dh)."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
               v.transpose(0, 2, 1, 3), causal=causal, window=window,
               interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def decode_attention_bshd(q, k, v, lengths, *, interpret: bool | None = None):
    """q (B,1,H,dh), cache k/v (B,T,KvE,dh), lengths (B,) -> (B,1,H,dh)."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode(q[:, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                lengths, interpret=interpret)
    return o[:, None]


def decode_attention_resident_bshd(q, k, v, lengths, rows, kv_rows=None, *,
                                   inv_rows=None,
                                   interpret: bool | None = None):
    """Placement-driven decode: model layout q (B,1,H,dh), cache k/v
    (B,T,KvE,dh), ``rows`` (R,) the physical q-head rows this dispatch
    covers (the device's resident slice, slot-grouped).  Returns the
    compacted (B,1,R,dh) slice in ``rows`` order — or, when ``inv_rows``
    (the scatter map with R == H) is given, the full (B,1,H,dh) tensor in
    physical q order, ready for the wo projection."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode_res(q[:, 0], k.transpose(0, 2, 1, 3),
                    v.transpose(0, 2, 1, 3), lengths, rows, kv_rows,
                    interpret=interpret)
    if inv_rows is not None:
        o = jnp.take(o, inv_rows, axis=1)
    return o[:, None]


def decode_attention_int8_resident_bshd(q, k_q8, k_sc, v_q8, v_sc, lengths,
                                        rows, kv_rows=None, *, inv_rows=None,
                                        interpret: bool | None = None):
    """int8-KV twin of :func:`decode_attention_resident_bshd`: cache
    k_q8/v_q8 (B,T,KvE,dh) int8 with per-(token, head) scales k_sc/v_sc
    (B,T,KvE) — dequantized in VMEM by the fused kernel."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode_i8_res(q[:, 0], k_q8.transpose(0, 2, 1, 3),
                       k_sc.transpose(0, 2, 1), v_q8.transpose(0, 2, 1, 3),
                       v_sc.transpose(0, 2, 1), lengths, rows, kv_rows,
                       interpret=interpret)
    if inv_rows is not None:
        o = jnp.take(o, inv_rows, axis=1)
    return o[:, None]


def decode_attention_paged_bshd(q, k_pages, v_pages, lengths, page_map,
                                rows, kv_rows=None, *, inv_rows=None,
                                interpret: bool | None = None):
    """Paged decode in model layout: q (B,1,H,dh), page store k/v
    (n_pages, P, KvE, dh), ``page_map`` (B, np) int32 physical page ids
    in logical order (callers clamp unmapped -1 entries to 0 — the
    length mask hides them).  ``rows``/``inv_rows`` as in
    :func:`decode_attention_resident_bshd`."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode_paged(q[:, 0], k_pages.transpose(0, 2, 1, 3),
                      v_pages.transpose(0, 2, 1, 3), lengths, page_map,
                      rows, kv_rows, interpret=interpret)
    if inv_rows is not None:
        o = jnp.take(o, inv_rows, axis=1)
    return o[:, None]


def decode_attention_int8_paged_bshd(q, k_q8, k_sc, v_q8, v_sc, lengths,
                                     page_map, rows, kv_rows=None, *,
                                     inv_rows=None,
                                     interpret: bool | None = None):
    """int8-KV twin of :func:`decode_attention_paged_bshd`: page store
    k_q8/v_q8 (n_pages, P, KvE, dh) int8 with per-(token, head) scale
    pages k_sc/v_sc (n_pages, P, KvE)."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode_i8_paged(q[:, 0], k_q8.transpose(0, 2, 1, 3),
                         k_sc.transpose(0, 2, 1)[..., None],
                         v_q8.transpose(0, 2, 1, 3),
                         v_sc.transpose(0, 2, 1)[..., None],
                         lengths, page_map, rows, kv_rows,
                         interpret=interpret)
    if inv_rows is not None:
        o = jnp.take(o, inv_rows, axis=1)
    return o[:, None]


def decode_attention_ring_bshd(q, k, v, lengths, slot_pos, *, window: int,
                               rows, kv_rows=None, inv_rows=None,
                               interpret: bool | None = None):
    """Sliding-window ring-cache decode in model layout: q (B,1,H,dh),
    ring k/v (B,window,KvE,dh), ``slot_pos`` (window,) the absolute
    position each ring slot holds — the kernel masks by position instead
    of rotating the buffer (softmax is permutation-invariant over kv)."""
    interpret = _on_cpu() if interpret is None else interpret
    o = _decode_ring(q[:, 0], k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3), lengths, slot_pos, rows,
                     kv_rows, window=window, interpret=interpret)
    if inv_rows is not None:
        o = jnp.take(o, inv_rows, axis=1)
    return o[:, None]


def rwkv6(r, k, v, w, u, state, *, interpret: bool | None = None):
    """Model layout: r/k/v/w (B,S,H,dh), u (H,dh), state (B,H,dh,dh).
    Returns y (B,S,H,dh) f32, new state."""
    interpret = _on_cpu() if interpret is None else interpret
    tr = lambda t: t.transpose(0, 2, 1, 3)
    y, sT = _rwkv6(tr(r), tr(k), tr(v), tr(w), u, state, interpret=interpret)
    return y.transpose(0, 2, 1, 3), sT
