"""Pallas TPU flash-decode: one query token vs a long head-sharded KV cache
— the paper's dominant inference object (growing K/V caches).

Grid (B, H, nk): kv blocks stream through VMEM sequentially while (m, l,
acc) persist in scratch. The valid cache length arrives via scalar prefetch
(SMEM) so fully-invalid kv blocks are skipped — decode cost tracks the
*actual* sequence length, not the cache capacity, which is exactly the
m_i(τ)-growth behaviour the paper's cost model prices.

``decode_attention_int8`` is the fused int8-KV variant (EXPERIMENTS.md
§Perf H1/H3 note): the kernel reads the int8 cache + per-(token, head)
scales directly from HBM and dequantizes in VMEM — cache read traffic is
halved vs bf16, which is what makes the optimized decode cells approach
the resident-state roofline on TPU.

VMEM per step ≈ 2·bk·dh·bytes + dh·4; bk=1024, dh=128, bf16 ⇒ ~0.5 MB.

``decode_attention_resident`` / ``decode_attention_int8_resident`` are the
placement-driven variants: the grid is (B, R, nk) where R is the number of
(layer, head) rows THIS device actually hosts, and the q/kv head rows to
read arrive as scalar-prefetched gather maps (``rows`` / ``kv_rows``) that
the BlockSpec index maps consult — exactly the block-sparse dispatch
pattern, applied to the paper's per-(layer, device) head placement.  A
slot hosting 3 of 32 heads at some layer runs 3/32 of the full grid with
no padding to the global head count; ragged per-layer head splits (the
block graph places heads per layer since PR 2) cost nothing beyond their
resident rows.  ``placement_to_head_slices`` (core.placement_bridge)
derives the row maps from the same BlockGraph placement the cost model
and the migration machinery price.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 1024
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, nk: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    length = len_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (1, dh)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_ref[...]                                # (1, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _kernel_int8(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, scale: float, bk: int, nk: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    length = len_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                    # (1, dh)
        # fused dequant in VMEM: int8 block + per-token scales
        ksc = ks_ref[0, 0].astype(jnp.float32)                 # (bk, 1)
        vsc = vs_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32) * ksc              # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32) * vsc
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_int8(q, k_q8, k_sc, v_q8, v_sc, lengths, *,
                          bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (B,H,dh) bf16/f32; k_q8/v_q8: (B,KvE,T,dh) int8;
    k_sc/v_sc: (B,KvE,T) f32 per-(token, head) scales; lengths: (B,).
    The dense grid is the resident grid with the identity gather map
    (rows = arange(H)), so this is a thin wrapper — one pallas_call
    builder per kernel body, not two to keep in sync."""
    rows = jnp.arange(q.shape[1], dtype=jnp.int32)
    return decode_attention_int8_resident(q, k_q8, k_sc, v_q8, v_sc,
                                          lengths, rows, bk=bk,
                                          interpret=interpret)


def _kernel_resident(len_ref, qr_ref, kr_ref, *rest, scale, bk, nk):
    """Resident-slice wrapper of ``_kernel``: the two extra scalar-prefetch
    refs (q/kv gather maps) are consumed by the BlockSpec index maps, not
    the body — the body only reads the valid length."""
    _kernel(len_ref, *rest, scale=scale, bk=bk, nk=nk)


def _kernel_int8_resident(len_ref, qr_ref, kr_ref, *rest, scale, bk, nk):
    _kernel_int8(len_ref, *rest, scale=scale, bk=bk, nk=nk)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_resident(q, k, v, lengths, rows, kv_rows=None, *,
                              bk: int = DEFAULT_BK, interpret: bool = False):
    """Flash-decode over only the head rows resident on this device.

    q: (B, H, dh) — the FULL q-head axis in its physical layout; k, v:
    (B, KvE, T, dh); lengths: (B,) int32 valid cache lengths; rows: (R,)
    int32 physical q-head rows this device hosts (R ≤ H, ragged per
    (layer, slot)); kv_rows: (R,) int32 KV rows (defaults to
    ``rows // (H // KvE)`` — group-consistent layouts keep the GQA
    q→kv association under this rule even after migrations).

    Grid (B, R, nk): row r of the grid computes head ``rows[r]``; the
    gather maps are scalar-prefetched so the DMA engine reads exactly the
    resident K/V blocks.  Returns the COMPACTED (B, R, dh) slice in
    ``rows`` order (callers holding the full head axis scatter it back
    with the inverse map).
    """
    B, H, dh = q.shape
    KvE, T = k.shape[1], k.shape[2]
    assert H % KvE == 0
    G = H // KvE
    if kv_rows is None:
        kv_rows = rows // G
    R = rows.shape[0]
    bk = min(bk, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    scale = 1.0 / math.sqrt(dh)
    q4 = q[:, :, None, :]                                  # (B,H,1,dh)

    kernel = functools.partial(_kernel_resident, scale=scale, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, R, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, h, ik, lens, qr, kr: (b, qr[h], 0, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, ik, lens, qr, kr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), rows.astype(jnp.int32),
      kv_rows.astype(jnp.int32), q4, k, v)
    return out[:, :, 0, :]


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_int8_resident(q, k_q8, k_sc, v_q8, v_sc, lengths, rows,
                                   kv_rows=None, *, bk: int = DEFAULT_BK,
                                   interpret: bool = False):
    """Resident-slice variant of ``decode_attention_int8`` (kept in sync):
    same (B, R, nk) grid and scalar-prefetched gather maps as
    ``decode_attention_resident``, fused int8 dequant in VMEM.  Returns
    the compacted (B, R, dh) slice in ``rows`` order."""
    B, H, dh = q.shape
    KvE, T = k_q8.shape[1], k_q8.shape[2]
    assert H % KvE == 0
    G = H // KvE
    if kv_rows is None:
        kv_rows = rows // G
    R = rows.shape[0]
    bk = min(bk, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    scale = 1.0 / math.sqrt(dh)
    q4 = q[:, :, None, :]
    ks4 = k_sc[..., None]                                      # (B,KvE,T,1)
    vs4 = v_sc[..., None]

    kernel = functools.partial(_kernel_int8_resident, scale=scale, bk=bk,
                               nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, R, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, h, ik, lens, qr, kr: (b, qr[h], 0, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, 1, bk, 1),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, 1, bk, 1),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, ik, lens, qr, kr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), rows.astype(jnp.int32),
      kv_rows.astype(jnp.int32), q4, k_q8, ks4, v_q8, vs4)
    return out[:, :, 0, :]


def _kernel_paged(len_ref, qr_ref, kr_ref, pt_ref, *rest, scale, bk, nk):
    """Paged wrapper of ``_kernel``: the page table (4th scalar-prefetch
    ref) is consumed by the kv BlockSpec index maps — the body is the
    SAME flash body, with the block size equal to the page size and
    ``k_start = page * page_size`` the logical position (the page map is
    kept in logical order, so the prefix length mask still skips every
    dead page)."""
    _kernel(len_ref, *rest, scale=scale, bk=bk, nk=nk)


def _kernel_int8_paged(len_ref, qr_ref, kr_ref, pt_ref, *rest, scale, bk,
                       nk):
    _kernel_int8(len_ref, *rest, scale=scale, bk=bk, nk=nk)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_paged_resident(q, k_pages, v_pages, lengths, page_map,
                                    rows, kv_rows=None, *,
                                    interpret: bool = False):
    """Flash-decode over a PAGED cache: resident head rows × live pages.

    q: (B, H, dh); k_pages/v_pages: (n_pages, KvE, P, dh) — the pooled
    page store, no batch axis (pages are the allocation unit, any page
    can serve any slot); lengths: (B,) int32 valid lengths; page_map:
    (B, np) int32 PHYSICAL page ids in logical order — entries past a
    slot's live pages may hold any in-range id (callers clamp their -1
    sentinels to 0): the length mask skips those blocks before their
    garbage is read.  rows/kv_rows as in
    :func:`decode_attention_resident`.

    Grid (B, R, np): the kv BlockSpec index maps walk
    ``(page_map[b, ip], kv_rows[h])`` — block-sparse dispatch in BOTH the
    head axis (placement) and the sequence axis (paging), so a slot's
    decode reads exactly its resident heads' live pages and no dense
    ``max_seq`` extent exists anywhere.  Returns the compacted
    (B, R, dh) slice in ``rows`` order.
    """
    B, H, dh = q.shape
    n_pages, KvE, P = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    assert H % KvE == 0
    G = H // KvE
    if kv_rows is None:
        kv_rows = rows // G
    R = rows.shape[0]
    np_log = page_map.shape[1]
    scale = 1.0 / math.sqrt(dh)
    q4 = q[:, :, None, :]                                  # (B,H,1,dh)

    kernel = functools.partial(_kernel_paged, scale=scale, bk=P, nk=np_log)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, R, np_log),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, h, ip, lens, qr, kr, pt:
                         (b, qr[h], 0, 0)),
            pl.BlockSpec((1, 1, P, dh),
                         lambda b, h, ip, lens, qr, kr, pt:
                         (pt[b, ip], kr[h], 0, 0)),
            pl.BlockSpec((1, 1, P, dh),
                         lambda b, h, ip, lens, qr, kr, pt:
                         (pt[b, ip], kr[h], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, ip, lens, qr, kr, pt:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), rows.astype(jnp.int32),
      kv_rows.astype(jnp.int32), page_map.astype(jnp.int32),
      q4, k_pages, v_pages)
    return out[:, :, 0, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_int8_paged_resident(q, k_q8, k_sc, v_q8, v_sc,
                                         lengths, page_map, rows,
                                         kv_rows=None, *,
                                         interpret: bool = False):
    """Paged + fused-int8 variant: k_q8/v_q8 (n_pages, KvE, P, dh) int8,
    k_sc/v_sc (n_pages, KvE, P, 1) f32 per-(token, head) scale pages —
    scales page exactly like values, so a migrated page carries its own
    dequant state."""
    B, H, dh = q.shape
    n_pages, KvE, P = k_q8.shape[0], k_q8.shape[1], k_q8.shape[2]
    assert H % KvE == 0
    G = H // KvE
    if kv_rows is None:
        kv_rows = rows // G
    R = rows.shape[0]
    np_log = page_map.shape[1]
    scale = 1.0 / math.sqrt(dh)
    q4 = q[:, :, None, :]

    kernel = functools.partial(_kernel_int8_paged, scale=scale, bk=P,
                               nk=np_log)
    kv_spec = pl.BlockSpec((1, 1, P, dh),
                           lambda b, h, ip, lens, qr, kr, pt:
                           (pt[b, ip], kr[h], 0, 0))
    sc_spec = pl.BlockSpec((1, 1, P, 1),
                           lambda b, h, ip, lens, qr, kr, pt:
                           (pt[b, ip], kr[h], 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, R, np_log),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, h, ip, lens, qr, kr, pt:
                         (b, qr[h], 0, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, ip, lens, qr, kr, pt:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), rows.astype(jnp.int32),
      kv_rows.astype(jnp.int32), page_map.astype(jnp.int32),
      q4, k_q8, k_sc, v_q8, v_sc)
    return out[:, :, 0, :]


def _kernel_ring(len_ref, qr_ref, kr_ref, q_ref, k_ref, v_ref, pos_ref,
                 o_ref, m_ref, l_ref, acc_ref, *, scale: float, bk: int,
                 nk: int, window: int):
    """Ring-buffer flash decode: softmax is permutation-invariant over
    the kv axis, so the ring needs NO physical rotation — each block's
    absolute positions stream in as a VMEM input (the ring's ``pos``
    array) and validity is decided per column.  Unlike the linear
    kernels, validity is NOT a block-axis prefix, so every block
    computes and the mask must also zero ``p`` explicitly: a
    fully-invalid block leaves ``m`` at NEG_INF and ``exp(s - m)`` would
    otherwise be exp(0) = 1."""
    b = pl.program_id(0)
    ik = pl.program_id(2)
    length = len_ref[b]                      # query position + 1

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (1, dh)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pc = pos_ref[0][None, :]                              # (1, bk) abs pos
    valid = (pc < length) & (pc >= length - window)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention_ring_resident(q, k, v, lengths, slot_pos, rows,
                                   kv_rows=None, *, window: int,
                                   bk: int = DEFAULT_BK,
                                   interpret: bool = False):
    """Sliding-window (ring cache) flash decode over resident head rows.

    q: (B, H, dh); k, v: (B, KvE, window, dh) ring buffers (slot
    ``t % window`` holds position t); lengths: (B,) int32 = query
    position + 1; slot_pos: (window,) int32 the absolute position held by
    each ring slot (empty slots hold a large negative, so they never pass
    the window mask); rows/kv_rows: the same scalar-prefetched gather
    maps as :func:`decode_attention_resident` — the ring closes PR 4's
    kernel-path hole with the SAME machinery, plus one (1, window)
    position stream the mask consults instead of a block-prefix length
    test."""
    B, H, dh = q.shape
    KvE, T = k.shape[1], k.shape[2]
    assert T == window, (T, window)
    assert H % KvE == 0
    G = H // KvE
    if kv_rows is None:
        kv_rows = rows // G
    R = rows.shape[0]
    bk = min(bk, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    scale = 1.0 / math.sqrt(dh)
    q4 = q[:, :, None, :]
    pos2 = slot_pos.astype(jnp.int32)[None, :]             # (1, window)

    kernel = functools.partial(_kernel_ring, scale=scale, bk=bk, nk=nk,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, R, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh),
                         lambda b, h, ik, lens, qr, kr: (b, qr[h], 0, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, ik, lens, qr, kr: (b, kr[h], ik, 0)),
            pl.BlockSpec((1, bk),
                         lambda b, h, ik, lens, qr, kr: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh),
                               lambda b, h, ik, lens, qr, kr: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, R, 1, dh), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), rows.astype(jnp.int32),
      kv_rows.astype(jnp.int32), q4, k, v, pos2)
    return out[:, :, 0, :]


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, lengths, *, bk: int = DEFAULT_BK,
                     interpret: bool = False):
    """q: (B,H,dh); k,v: (B,KvE,T,dh); lengths: (B,) int32 valid lengths.
    Returns (B,H,dh).  Thin wrapper over the resident variant with the
    identity gather map (rows = arange(H)) — see
    :func:`decode_attention_int8` for the rationale."""
    rows = jnp.arange(q.shape[1], dtype=jnp.int32)
    return decode_attention_resident(q, k, v, lengths, rows, bk=bk,
                                     interpret=interpret)
