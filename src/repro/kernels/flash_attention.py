"""Pallas TPU flash attention (prefill hot spot).

Causal (optionally sliding-window) GQA attention with online softmax.
Grid (B, H, nq, nk) — the trailing kv axis is TPU-sequential, so the
(m, l, acc) running statistics live in VMEM scratch across kv steps.
BlockSpec tiling: q tile (bq, dh), k/v tiles (bk, dh) — MXU-aligned
(dh, bq, bk multiples of 128 at full size), everything resident in VMEM:
  vmem ≈ (bq + 2·bk)·dh·bytes + bq·dh·4 (acc)  « 16 MB for bq=bk=512.
Fully-above-diagonal kv blocks are skipped (@pl.when) — causal FLOP
savings without grid surgery.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    q_last = q_start + bq - 1

    # causal block skip: any work iff k_start <= q_last; window skip: the
    # block's newest key k_start+bk-1 must be > q_start - window
    run = True
    if causal:
        run = k_start <= q_last
        if window > 0:
            run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = cols <= rows
            if window > 0:
                mask = jnp.logical_and(mask, cols > rows - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (B,H,Sq,dh); k,v: (B,KvE,Skv,dh); H % KvE == 0.
    Returns (B,H,Sq,dh)."""
    B, H, Sq, dh = q.shape
    KvE, Skv = k.shape[1], k.shape[2]
    assert H % KvE == 0, (H, KvE)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(dh)
    G = H // KvE

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, dh), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
