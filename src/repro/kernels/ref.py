"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Layouts match the kernels: attention uses (B, H, S, dh); the model-side
wrappers in ops.py transpose from the model's (B, S, H, dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B,H,Sq,dh); k,v: (B,KvE,Skv,dh). GQA: H % KvE == 0.
    Returns (B,H,Sq,dh) in q.dtype; softmax in f32."""
    B, H, Sq, dh = q.shape
    KvE, Skv = k.shape[1], k.shape[2]
    G = H // KvE
    scale = scale if scale is not None else 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(B, KvE, G, Sq, dh)
    s = jnp.einsum("begsd,betd->begst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("begst,betd->begsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, dh).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, scale: float | None = None):
    """q: (B,H,dh) one query token; k,v: (B,KvE,T,dh); lengths: (B,) valid
    cache lengths. Returns (B,H,dh)."""
    B, H, dh = q.shape
    KvE, T = k.shape[1], k.shape[2]
    G = H // KvE
    scale = scale if scale is not None else 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(B, KvE, G, dh)
    s = jnp.einsum("begd,betd->begt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(T)[None, :] < lengths[:, None]          # (B,T)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("begt,betd->begd", p, v.astype(jnp.float32))
    return o.reshape(B, H, dh).astype(q.dtype)


def rwkv6_ref(r, k, v, w, u, state):
    """WKV6 recurrence. r,k,v,w: (B,H,S,dh); u: (H,dh);
    state: (B,H,dh,dh) f32 (S[i,j] = key i, value j).
    Returns y (B,H,S,dh) f32, final state."""
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, rkvw):
        r_t, k_t, v_t, w_t = rkvw                  # (B,H,dh)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S)
        bonus = jnp.einsum("bhi,hi,bhi->bh", r_t, u, k_t)
        y = y + bonus[..., None] * v_t
        S = w_t[..., None] * S + k_t[..., None] * v_t[:, :, None, :]
        return S, y

    seq = tuple(jnp.moveaxis(t, 2, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 2), state
