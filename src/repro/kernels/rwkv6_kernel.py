"""Pallas TPU chunked WKV6 recurrence (RWKV-6 time-mix hot spot).

Grid (B, H, n_chunks): chunks stream sequentially while the per-head state
S ∈ R^{dh×dh} persists in VMEM scratch (f32).  Inside a chunk the strictly
sequential recurrence runs as a fori_loop over time steps with all operands
VMEM-resident — HBM traffic is exactly one read of (r,k,v,w) and one write
of y per element, the memory-bound optimum for this op.  dh = 64 aligns the
state to half a VREG tile; chunk = 128 keeps the per-chunk working set at
4·chunk·dh·4B + dh²·4B ≈ 150 KB.

The recurrence (per head, f32):
  y_t = r_t·S + (r_t·(u⊙k_t)) v_t
  S  <- diag(w_t)·S + k_tᵀ v_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            state, *, chunk: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)    # (chunk, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # (1, dh) -> use row 0

    def step(t, carry):
        S = state[...]
        r_t = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)     # (1, dh)
        k_t = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)
        v_t = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
        w_t = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
        y_t = jax.lax.dot_general(r_t, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        bonus = jnp.sum(r_t * u * k_t)                      # scalar
        y_t = y_t + bonus * v_t
        y_ref[0, 0, t, :] = y_t[0].astype(y_ref.dtype)
        state[...] = w_t.T * S + k_t.T * v_t                # (dh,dh)
        return carry

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ic == nc - 1)
    def _final():
        sT_ref[0, 0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(r, k, v, w, u, state, *, chunk: int = DEFAULT_CHUNK,
                  interpret: bool = False):
    """r,k,v,w: (B,H,S,dh); u: (H,dh); state: (B,H,dh,dh) f32.
    Returns y (B,H,S,dh) f32, final state (B,H,dh,dh) f32."""
    B, H, S, dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, dh), lambda b, h, ic: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, dh), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sT
