"""Sharded, atomic, async checkpointing (fault-tolerance substrate).

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per pytree leaf (keyed by a
flattened path), a ``manifest.json`` carrying tree structure, shapes,
dtypes and content hashes, and a ``COMMIT`` marker written last — a crashed
writer never produces a readable checkpoint (atomicity via marker +
temp-dir rename).  ``save_async`` hands the host transfer to a writer
thread so the train loop overlaps I/O with compute.  Restore validates
hashes and re-shards onto the current mesh via ``jax.device_put`` with the
caller's shardings — this is also the *elastic restart* path (a checkpoint
written on one mesh restores onto another).
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> Path:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # device -> host
        return self._write(step, host)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # transfer before returning

        def work():
            self._write(step, host)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {"step": step, "leaves": {}}
        for key, arr in flat.items():
            arr = np.asarray(arr)
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "COMMIT").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``like_tree``; device_put with
        ``shardings`` when given (elastic re-shard onto the current mesh)."""
        src = self.dir / f"step_{step:08d}"
        manifest = json.loads((src / "manifest.json").read_text())
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        sh_flat = None
        if shardings is not None:
            sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        leaves = []
        for i, (path, leaf) in enumerate(flat_like):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                           for p in path)
            meta = manifest["leaves"][key]
            arr = np.load(src / meta["file"])
            if verify:
                h = hashlib.sha1(arr.tobytes()).hexdigest()
                if h != meta["sha1"]:
                    raise IOError(f"checkpoint corruption at {key}")
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
