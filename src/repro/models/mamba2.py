"""Mamba-2 (SSD) block — used by the Zamba2 hybrid backbone.

Simplified-but-real SSD: fused in-proj -> (z, x, B, C, dt), causal depthwise
conv over (x,B,C), scalar-per-head decay a = exp(-exp(A_log)*dt), state
h in R^{nh x dh x n_state}, y = C.h + D*x, gated RMSNorm, out-proj.
ngroups = 1. Decode state: conv tail (width-1 tokens) + SSM state h — O(1)
in sequence length.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.partitioning import Partitioner


def mamba_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv


def init_mamba_layer(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, nh, dh, ns, cw = mamba_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * ns
    return {
        "ln": jnp.ones((D,), dt),
        "w_in": L.dense_init(ks[0], D, (D, 2 * d_in + 2 * ns + nh), dt),
        "conv_w": L.dense_init(ks[1], cw, (cw, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), dt),
        "w_out": L.dense_init(ks[2], d_in, (d_in, D), dt),
    }


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv. xBC: (B,S,C); conv_w: (cw,C).
    conv_state: (B,cw-1,C) tail of the previous chunk (decode) or None
    (prefill, zero history). Returns (out (B,S,C), new_state)."""
    B, S, C = xBC.shape
    cw = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, cw - 1, C), xBC.dtype)
    full = jnp.concatenate([conv_state, xBC], axis=1)      # (B, S+cw-1, C)
    # windows: out[t] = sum_i w[i] * full[t+i]
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(cw):
        out = out + full[:, i:i + S, :].astype(jnp.float32) * \
            conv_w[i].astype(jnp.float32)
    out = out + conv_b.astype(jnp.float32)
    new_state = full[:, -(cw - 1):, :]
    return jax.nn.silu(out).astype(xBC.dtype), new_state


def ssd_scan(xh, Bt, Ct, a, dtv, h0):
    """SSD recurrence (oracle, f32).

    xh: (B,S,nh,dh); Bt,Ct: (B,S,ns); a: (B,S,nh) decay in (0,1);
    dtv: (B,S,nh); h0: (B,nh,dh,ns). Returns y (B,S,nh,dh), hT.
    """
    xh, Bt, Ct, a, dtv = (t.astype(jnp.float32) for t in (xh, Bt, Ct, a, dtv))

    def step(h, inp):
        x_t, b_t, c_t, a_t, dt_t = inp
        dx = x_t * dt_t[..., None]                          # (B,nh,dh)
        h = a_t[..., None, None] * h + dx[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhds,bs->bhd", h, c_t)
        return h, y

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bt, Ct, a, dtv))
    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), seq)
    return jnp.moveaxis(ys, 0, 1), h


def mamba_block(cfg: ModelConfig, p: dict, x, state: Dict, part: Partitioner):
    """x: (B,S,D); state {"conv": (B,cw-1,C), "ssm": (B,nh,dh,ns)} or zeros.
    Returns (out, new_state)."""
    d_in, nh, dh, ns, cw = mamba_dims(cfg)
    B, S, _ = x.shape
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["w_in"]
    z, xs, Bt, Ct, dtl = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ns, 2 * d_in + 2 * ns], axis=-1)
    xBC = jnp.concatenate([xs, Bt, Ct], axis=-1)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], state["conv"])
    xs, Bt, Ct = jnp.split(xBC, [d_in, d_in + ns], axis=-1)
    dtv = jax.nn.softplus(dtl.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dtv)                         # (B,S,nh)
    xh = xs.reshape(B, S, nh, dh)
    xh = part.constrain(xh, ("batch", "seq", "ssm_heads", None))
    y, new_ssm = ssd_scan(xh, Bt, Ct, a, dtv, state["ssm"])
    new_ssm = part.constrain(new_ssm, ("batch", "ssm_heads", None, None))
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2's norm(y * silu(z)))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(y.astype(x.dtype), p["norm"], cfg.norm_eps)
    y = part.constrain(y, ("batch", "seq", "d_ff"))
    out = y @ p["w_out"]
    return part.constrain(out, ("batch", "res_seq", "d_model")), \
        {"conv": new_conv, "ssm": new_ssm}


def zero_mamba_state(cfg: ModelConfig, batch: int, lead=()):
    d_in, nh, dh, ns, cw = mamba_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    C = d_in + 2 * ns
    return {
        "conv": jnp.zeros(lead + (batch, cw - 1, C), dt),
        "ssm": jnp.zeros(lead + (batch, nh, dh, ns), jnp.float32),
    }
