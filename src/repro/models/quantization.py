"""Weight-only int8 quantization for serving (beyond-paper §Perf lever).

Symmetric per-last-axis int8: a float weight W becomes
``{"q8": int8, "sc": f32[last_dim]}`` with W ≈ q8 * sc.  Dequantization
happens inside the layer-scan body (per-layer slices), so the resident
footprint is int8 (2x smaller, and for the big decode cells it removes the
need for FSDP param storage entirely — the per-step all-gather of bf16
weights disappears from the collective term).

Only matmul weights of the transformer family are quantized (attention
projections, MLP/MoE experts, embeddings, lm head); norms, biases, gates
and router weights stay in full precision.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

# base (unstacked) rank of each quantizable weight; leading stack axes
# (the lax.scan layer dim, VLM supergroups) keep per-layer scales
_BASE_NDIM = {"wq": 3, "wk": 3, "wv": 3, "wo": 3,
              "tok_embed": 2, "lm_head": 2,
              "w_gate": 2, "w_up": 2, "w_down": 2}    # 3 inside "moe"
QUANT_NAMES = tuple(_BASE_NDIM)


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and "q8" in leaf


def quantize_weight(w: jnp.ndarray, base_ndim: int) -> dict:
    """Symmetric int8; scale per (stack dims..., last axis)."""
    lead = w.ndim - base_ndim
    red = tuple(range(lead, w.ndim - 1))
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=red)  # (lead..,last)
    sc = jnp.maximum(absmax, 1e-8) / 127.0
    sc_b = sc.reshape(sc.shape[:-1] + (1,) * (base_ndim - 1) + sc.shape[-1:])
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / sc_b), -127, 127)
    return {"q8": q.astype(jnp.int8), "sc": sc.astype(jnp.float32)}


def dequantize_weight(leaf, dtype=jnp.bfloat16):
    if not is_quantized(leaf):
        return leaf
    q8, sc = leaf["q8"], leaf["sc"]
    sc_b = sc.reshape(sc.shape[:-1] + (1,) * (q8.ndim - sc.ndim)
                      + sc.shape[-1:])
    return (q8.astype(jnp.float32) * sc_b).astype(dtype)


def wt(p: dict, name: str, dtype=jnp.bfloat16):
    """Weight accessor used by the model code: transparent dequant."""
    leaf = p[name]
    if is_quantized(leaf):
        return dequantize_weight(leaf, dtype)
    return leaf


def quantize_params(params) -> Any:
    """Quantize every QUANT_NAMES leaf in a param tree."""
    def visit(tree, parent=""):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k in QUANT_NAMES and hasattr(v, "ndim") and v.ndim >= 2 \
                        and jnp.issubdtype(v.dtype, jnp.floating):
                    base = _BASE_NDIM[k]
                    if parent == "moe" and k.startswith("w_"):
                        base = 3                      # (E, D, F) experts
                    out[k] = quantize_weight(v, base)
                else:
                    out[k] = visit(v, parent=k)
            return out
        return tree
    return visit(params)
