"""Logical-axis partitioning (MaxText-style axis rules).

Models annotate intermediates with *logical* axis names; a
:class:`Partitioner` maps them to mesh axes and applies
``with_sharding_constraint``.  The default :class:`NullPartitioner` is a
no-op so every model runs unsharded on one device (smoke tests).

Logical axes used across the codebase::

  batch seq heads kv_heads head_dim d_model d_ff vocab experts
  ssm_heads ssm_state cache_seq img_seq
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[None, str, Tuple[str, ...]]


class Partitioner:
    """Maps logical axis names to mesh axes and constrains intermediates."""

    def __init__(self, mesh: Optional[Mesh], rules: Dict[str, MeshAxis]):
        self.mesh = mesh
        self.rules = dict(rules)

    # -- specs ---------------------------------------------------------------
    def spec(self, axes: Sequence[Optional[str]]) -> P:
        used: set = set()
        parts = []
        for ax in axes:
            m = self.rules.get(ax) if ax is not None else None
            # a mesh axis may appear at most once in a spec; later wins -> None
            if m is None:
                parts.append(None)
                continue
            key = tuple(m) if isinstance(m, tuple) else (m,)
            if used & set(key):
                parts.append(None)
                continue
            used |= set(key)
            parts.append(m)
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]]) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(axes))

    # -- constraint ----------------------------------------------------------
    def constrain(self, x, axes: Sequence[Optional[str]]):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(axes)))


class NullPartitioner(Partitioner):
    def __init__(self):
        super().__init__(None, {})

    def constrain(self, x, axes):  # noqa: D401 - no-op
        return x

    def spec(self, axes):
        return P()


NULL = NullPartitioner()


# ---------------------------------------------------------------------------
# Axis-rule presets (see DESIGN.md §4). `fsdp` = storage sharding of params
# over the data axis (gathered on use by GSPMD); used for training and for
# decode of models whose bf16 params exceed HBM under pure TP.
# ---------------------------------------------------------------------------

def rules_tp(data_axes: MeshAxis = ("data",), model_axis: str = "model",
             fsdp: bool = False, seq_over_data: bool = False,
             sp: bool = False) -> Dict[str, MeshAxis]:
    """Head-level TP (the paper's axis) + DP over batch.

    seq_over_data: shard the KV-cache sequence dim over the data axis
    (long_500k: batch=1 cannot use data parallelism).
    sp: Megatron-style sequence parallelism — the *residual stream*
    ("res_seq") shards its sequence dim over the model axis between blocks
    (all-gather into TP regions, reduce-scatter out); cuts saved-activation
    memory by tp and replaces all-reduce with reduce-scatter+all-gather.
    """
    rules: Dict[str, MeshAxis] = {
        "batch": data_axes if not seq_over_data else None,
        "seq": None,
        "res_seq": model_axis if sp else None,
        "heads": model_axis,
        "kv_heads": model_axis,
        "head_dim": None,
        "d_model": None,
        "d_ff": model_axis,
        "vocab": model_axis,
        "experts": None,
        "ssm_heads": model_axis,
        "ssm_state": None,
        "cache_seq": (data_axes if isinstance(data_axes, str) else data_axes[-1]) if seq_over_data else None,
        "img_seq": None,
        # param-storage-only axes
        "fsdp": (data_axes if isinstance(data_axes, str) else data_axes[-1]) if fsdp else None,
    }
    return rules


def rules_zero3(data_axes: MeshAxis) -> Dict[str, MeshAxis]:
    """Pure ZeRO-3 / FSDP layout: BOTH mesh axes carry data parallelism,
    no tensor parallelism at all — the right layout for models whose
    per-layer weights fit one chip (e.g. 8B on 256 chips): it replaces the
    per-layer TP boundary all-gather/all-reduce of activations with
    per-layer parameter gathers, ~7x less traffic at train_4k scale
    (EXPERIMENTS.md §Perf H2-3)."""
    return {
        "batch": data_axes, "seq": None, "res_seq": None,
        "heads": None, "kv_heads": None, "head_dim": None,
        "d_model": None, "d_ff": None, "vocab": None, "experts": None,
        "ssm_heads": None, "ssm_state": None, "cache_seq": None,
        "img_seq": None, "fsdp": data_axes,
    }


def make_partitioner(mesh: Optional[Mesh], *, fsdp: bool = False,
                     seq_over_data: bool = False, sp: bool = False,
                     layout: str = "tp") -> Partitioner:
    if mesh is None:
        return NullPartitioner()
    names = mesh.axis_names
    data_axes: MeshAxis
    if "pod" in names:
        data_axes = ("pod", "data")
    else:
        data_axes = ("data",)
    if layout == "zero3":
        all_axes = tuple(names)  # every axis is data-parallel
        return Partitioner(mesh, rules_zero3(all_axes))
    return Partitioner(mesh, rules_tp(data_axes=data_axes, fsdp=fsdp,
                                      seq_over_data=seq_over_data, sp=sp))
