"""Mixtral-style sparse MoE MLP: top-2 routing, softmax-renormalized gates.

Dispatch uses dense one-hot combine (einsum) — the standard TPU-friendly
formulation (no scatter): every expert processes the full token set masked by
its gate. With 8 experts / top-2 this is a 4x FLOP overhead over perfectly
packed dispatch; a capacity-bucketed dispatch variant is provided
(``capacity_factor > 0``) for the optimized path (§Perf) which restores
O(tokens * top_k) compute via gather/one-hot matmuls of size
(E, capacity, D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.partitioning import Partitioner
from repro.models.quantization import wt


def init_moe(key, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], D, (D, E), jnp.float32),
        "w_gate": dense_init(ks[1], D, (E, D, F), dtype),
        "w_up": dense_init(ks[2], D, (E, D, F), dtype),
        "w_down": dense_init(ks[3], F, (E, F, D), dtype),
    }


def router_probs(cfg: ModelConfig, p: dict, x):
    """(B,S,E) top-k gate weights (softmax over selected), plus aux stats."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    k = cfg.experts_per_token
    top_vals, top_idx = jax.lax.top_k(logits, k)                  # (B,S,k)
    top_w = jax.nn.softmax(top_vals, axis=-1)                     # renormalized
    gates = jnp.zeros_like(logits)
    gates = jnp.put_along_axis(gates, top_idx, top_w, axis=-1, inplace=False)
    # load-balancing auxiliary loss terms (Switch-style)
    probs_full = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs_full, axis=(0, 1))
    aux_loss = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return gates, aux_loss


def moe_block(cfg: ModelConfig, p: dict, x, part: Partitioner):
    """Dense-dispatch MoE. x: (B,S,D) -> (B,S,D), aux_loss scalar."""
    gates, aux = router_probs(cfg, p, x)                          # (B,S,E)
    gates = gates.astype(x.dtype)
    # Every expert computes on all tokens; outputs combined by gate weight.
    h = jnp.einsum("bsd,edf->bsef", x, wt(p, "w_gate", x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, wt(p, "w_up", x.dtype))
    h = jax.nn.silu(h) * u
    h = part.constrain(h, ("batch", "seq", "experts", "d_ff"))
    out = jnp.einsum("bsef,efd->bsed", h, wt(p, "w_down", x.dtype))
    out = jnp.einsum("bsed,bse->bsd", out, gates)
    return part.constrain(out, ("batch", "res_seq", "d_model")), aux


def moe_block_capacity(cfg: ModelConfig, p: dict, x, part: Partitioner,
                       capacity_factor: float = 1.25, group: int = 1024):
    """GShard-style grouped capacity dispatch (production path).

    Tokens are split into groups of ``group`` along the sequence dim; each
    group routes into per-expert buckets of capacity
    C = ceil(cf*k*group/E); overflow within a group is dropped (standard
    MoE semantics).  Grouping bounds the dispatch one-hot at
    (BG, n, E, C) ~ O(n²) *per group*, keeping dispatch ~4% of expert
    FLOPs; expert compute is O(N·k·cf) instead of dense-dispatch's O(N·E).
    Groups contain whole batch rows so data-sharding stays local.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    n = min(group, S)
    assert S % n == 0, (S, n)
    BG = B * (S // n)
    cap = max(int(capacity_factor * k * n / E), 1)
    gates, aux = router_probs(cfg, p, x)                           # (B,S,E)
    xg = x.reshape(BG, n, D)
    gt = gates.reshape(BG, n, E).astype(x.dtype)
    sel = gt > 0
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1            # (BG,n,E)
    keep = sel & (pos < cap)
    disp = (keep[..., None] &
            jax.nn.one_hot(pos, cap, dtype=jnp.bool_)).astype(x.dtype)
    disp = part.constrain(disp, ("batch", None, "experts", None))
    xe = jnp.einsum("gnd,gnec->gecd", xg, disp)                    # (BG,E,C,D)
    xe = part.constrain(xe, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wt(p, "w_gate", x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, wt(p, "w_up", x.dtype))
    h = part.constrain(h, ("batch", "experts", None, "d_ff"))
    ye = jnp.einsum("gecf,efd->gecd", h, wt(p, "w_down", x.dtype))
    comb = disp * gt[:, :, :, None]                                # (BG,n,E,C)
    y = jnp.einsum("gecd,gnec->gnd", ye, comb)
    out = y.reshape(B, S, D)
    return part.constrain(out, ("batch", "res_seq", "d_model")), aux
