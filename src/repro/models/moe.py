"""Mixtral-style sparse MoE MLP: top-2 routing, softmax-renormalized gates.

Dispatch uses dense one-hot combine (einsum) — the standard TPU-friendly
formulation (no scatter): every expert processes the full token set masked by
its gate. With 8 experts / top-2 this is a 4x FLOP overhead over perfectly
packed dispatch; a capacity-bucketed dispatch variant is provided
(``capacity_factor > 0``) for the optimized path (§Perf) which restores
O(tokens * top_k) compute via gather/one-hot matmuls of size
(E, capacity, D).

Physical expert layout (expert migration/replication): the weight stacks
``w_gate/w_up/w_down`` may hold the experts in an arbitrary *physical* row
order — or with extra replica rows — described by two side arrays in the
same param dict:

 - ``owner``  (Ep,) int32: physical row r holds a copy of logical expert
   ``owner[r]`` (Ep >= E when replicas exist);
 - ``share``  (Ep,) float32: row r's fraction of its logical expert's gate
   (replicas renormalize — rows owned by the same expert sum to 1).

The router always scores the E *logical* experts; physical rows compute,
and the combine scatters row outputs back into logical-expert order via a
one-hot matmul before the gate reduction.  With identity owner/share this
adds only exact-zero terms and 1.0 multiplies, and a pure permutation
gathers bit-identical per-expert outputs back into logical order — so
decode streams are bit-identical across applied expert migrations, the
same guarantee head migrations give via inverse head maps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.partitioning import Partitioner
from repro.models.quantization import wt


def expert_identity(n_experts: int, n_layers: int = 0):
    """Identity (owner, share) arrays: row r owns logical expert r with the
    full gate.  ``n_layers > 0`` returns stacked (L, E) arrays for the
    scanned layer pytree."""
    owner = jnp.arange(n_experts, dtype=jnp.int32)
    share = jnp.ones((n_experts,), jnp.float32)
    if n_layers:
        owner = jnp.broadcast_to(owner[None], (n_layers, n_experts))
        share = jnp.broadcast_to(share[None], (n_layers, n_experts))
    return owner, share


def _combine_physical(out, p, n_experts: int):
    """Scatter physical expert-row outputs (B,S,Ep,D) into logical-expert
    slots (B,S,E,D): z_e = sum_{r: owner[r]=e} share[r] * out_r."""
    share = p["share"].astype(out.dtype)
    onehot = jax.nn.one_hot(p["owner"], n_experts, dtype=out.dtype)  # (Ep,E)
    return jnp.einsum("bsrd,re->bsed", out * share[None, None, :, None],
                      onehot)


def replicate_expert(p: dict, expert: int) -> dict:
    """Append one physical replica of logical ``expert``: copy its weight
    rows and renormalize the gate share evenly across all of its copies.
    Accepts a per-layer moe dict ((E,D,F) weights) or the stacked layer
    pytree ((L,E,D,F)); installs identity owner/share first if absent."""
    stacked = p["w_gate"].ndim == 4
    ax = 1 if stacked else 0
    out = dict(p)
    if "owner" not in out:
        E = p["w_gate"].shape[ax]
        L = p["w_gate"].shape[0] if stacked else 0
        out["owner"], out["share"] = expert_identity(E, L)
    own, sh = out["owner"], out["share"]
    # per-layer physical source row currently owning ``expert``
    src = jnp.argmax((own == expert).astype(jnp.int32), axis=-1)
    for name in ("w_gate", "w_up", "w_down"):
        w = out[name]
        if stacked:
            idx = src.reshape((-1,) + (1,) * (w.ndim - 1))
            row = jnp.take_along_axis(w, idx, axis=1)          # (L,1,D,F)
            out[name] = jnp.concatenate([w, row], axis=1)
        else:
            out[name] = jnp.concatenate([w, w[src][None]], axis=0)
    new_col = jnp.full(own.shape[:-1] + (1,), expert, own.dtype)
    own = jnp.concatenate([own, new_col], axis=-1)
    sh = jnp.concatenate([sh, jnp.ones(new_col.shape, sh.dtype)], axis=-1)
    mask = own == expert
    cnt = jnp.sum(mask, axis=-1, keepdims=True).astype(sh.dtype)
    out["owner"] = own
    out["share"] = jnp.where(mask, 1.0 / cnt, sh)
    return out


def init_moe(key, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], D, (D, E), jnp.float32),
        "w_gate": dense_init(ks[1], D, (E, D, F), dtype),
        "w_up": dense_init(ks[2], D, (E, D, F), dtype),
        "w_down": dense_init(ks[3], F, (E, F, D), dtype),
    }


def router_probs(cfg: ModelConfig, p: dict, x):
    """(B,S,E) top-k gate weights (softmax over selected), plus aux stats."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    k = cfg.experts_per_token
    top_vals, top_idx = jax.lax.top_k(logits, k)                  # (B,S,k)
    top_w = jax.nn.softmax(top_vals, axis=-1)                     # renormalized
    gates = jnp.zeros_like(logits)
    gates = jnp.put_along_axis(gates, top_idx, top_w, axis=-1, inplace=False)
    # load-balancing auxiliary loss terms (Switch-style)
    probs_full = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs_full, axis=(0, 1))
    aux_loss = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return gates, aux_loss


def moe_block(cfg: ModelConfig, p: dict, x, part: Partitioner):
    """Dense-dispatch MoE. x: (B,S,D) -> (B,S,D), aux_loss scalar, plus the
    logical per-expert routed-token fraction (E,) observed on this call
    (the router-load signal the controller's expert cost model consumes)."""
    gates, aux = router_probs(cfg, p, x)                          # (B,S,E)
    freq = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))
    gates = gates.astype(x.dtype)
    # Every expert computes on all tokens; outputs combined by gate weight.
    # With a physical owner map the einsums run over the Ep physical rows
    # and the combine first scatters rows back into logical-expert order.
    h = jnp.einsum("bsd,edf->bsef", x, wt(p, "w_gate", x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, wt(p, "w_up", x.dtype))
    h = jax.nn.silu(h) * u
    h = part.constrain(h, ("batch", "seq", "experts", "d_ff"))
    out = jnp.einsum("bsef,efd->bsed", h, wt(p, "w_down", x.dtype))
    if "owner" in p:
        out = _combine_physical(out, p, cfg.n_experts)
    out = jnp.einsum("bsed,bse->bsd", out, gates)
    return part.constrain(out, ("batch", "res_seq", "d_model")), aux, freq


def moe_block_capacity(cfg: ModelConfig, p: dict, x, part: Partitioner,
                       capacity_factor: float = 1.25, group: int = 1024):
    """GShard-style grouped capacity dispatch (production path).

    Tokens are split into groups of ``group`` along the sequence dim; each
    group routes into per-expert buckets of capacity
    C = ceil(cf*k*group/E); overflow within a group is dropped (standard
    MoE semantics).  Grouping bounds the dispatch one-hot at
    (BG, n, E, C) ~ O(n²) *per group*, keeping dispatch ~4% of expert
    FLOPs; expert compute is O(N·k·cf) instead of dense-dispatch's O(N·E).
    Groups contain whole batch rows so data-sharding stays local.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    n = min(group, S)
    assert S % n == 0, (S, n)
    BG = B * (S // n)
    cap = max(int(capacity_factor * k * n / E), 1)
    gates, aux = router_probs(cfg, p, x)                           # (B,S,E)
    freq = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))
    gates = gates.astype(x.dtype)
    if "owner" in p:
        # expand logical gates onto physical rows: replicas of an expert
        # each dispatch the token with their share of its gate
        gates = jnp.take(gates, p["owner"], axis=-1) \
            * p["share"].astype(x.dtype)
    Ep = gates.shape[-1]
    xg = x.reshape(BG, n, D)
    gt = gates.reshape(BG, n, Ep)
    sel = gt > 0
    pos = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1            # (BG,n,E)
    keep = sel & (pos < cap)
    disp = (keep[..., None] &
            jax.nn.one_hot(pos, cap, dtype=jnp.bool_)).astype(x.dtype)
    disp = part.constrain(disp, ("batch", None, "experts", None))
    xe = jnp.einsum("gnd,gnec->gecd", xg, disp)                    # (BG,E,C,D)
    xe = part.constrain(xe, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wt(p, "w_gate", x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, wt(p, "w_up", x.dtype))
    h = part.constrain(h, ("batch", "experts", None, "d_ff"))
    ye = jnp.einsum("gecf,efd->gecd", h, wt(p, "w_down", x.dtype))
    comb = disp * gt[:, :, :, None]                                # (BG,n,E,C)
    y = jnp.einsum("gecd,gnec->gnd", ye, comb)
    out = y.reshape(B, S, D)
    return part.constrain(out, ("batch", "res_seq", "d_model")), aux, freq
