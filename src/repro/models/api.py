"""Unified model API: ``build_model(cfg)`` returns an object with

  init(key) -> params
  forward(params, tokens, **extras) -> (logits, aux)
  loss(params, batch) -> scalar
  init_decode_state(params, batch, max_seq, **extras) -> state
  prefill(params, state, tokens) -> (logits, state)
  decode_step(params, state, tokens) -> (logits, state)

Attention-backed models additionally expose the continuous-batching slot
API (``state["pos"]`` becomes a (B,) vector via
``init_decode_state(..., per_slot=True)``):

  prefill_bucketed(params, state, tokens, length) -> (logits, state)
  insert_slot(state, sub, slot) -> state

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input of
a (arch x shape) cell — weak-type-correct, shardable, no device allocation —
used by the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.partitioning import NULL, Partitioner
from repro.models.rwkv6 import RWKV6Model
from repro.models.transformer import TransformerLM
from repro.models.zamba2 import Zamba2Model


def build_model(cfg: ModelConfig, *, tp: int = 1, part: Partitioner = NULL,
                remat: str = "none", **kw):
    if cfg.family == "ssm":
        return RWKV6Model(cfg, tp=tp, part=part, remat=remat,
                          use_kernel=kw.get("use_kernel", False))
    if cfg.family == "hybrid":
        return Zamba2Model(cfg, tp=tp, part=part, remat=remat,
                           use_kernel=kw.get("use_kernel", False))
    return TransformerLM(cfg, tp=tp, part=part, remat=remat,
                         capacity_moe=kw.get("capacity_moe", False),
                         capacity_factor=kw.get("capacity_factor", 1.25),
                         use_kernel=kw.get("use_kernel", False))


# ---------------------------------------------------------------------------
# Stub modality frontends (assignment: [vlm]/[audio] backbones only)
# ---------------------------------------------------------------------------

N_IMAGE_TOKENS = 1601   # llama-3.2-vision tile embedding count (stub)


def batch_extras(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    """Extra (stubbed) frontend inputs for a batch: precomputed patch/frame
    embeddings per the assignment."""
    if cfg.family == "vlm":
        return {
            "img_embeds": jnp.zeros((batch, N_IMAGE_TOKENS, cfg.d_model), dtype),
            "img_mask": jnp.ones((batch, N_IMAGE_TOKENS), jnp.bool_),
        }
    return {}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    act = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), tok), "labels": sds((B, S), tok)}
        if cfg.family == "vlm":
            specs["img_embeds"] = sds((B, N_IMAGE_TOKENS, cfg.d_model), act)
            specs["img_mask"] = sds((B, N_IMAGE_TOKENS), jnp.bool_)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), tok)}
        if cfg.family == "vlm":
            specs["img_embeds"] = sds((B, N_IMAGE_TOKENS, cfg.d_model), act)
            specs["img_mask"] = sds((B, N_IMAGE_TOKENS), jnp.bool_)
        return specs
    # decode / long-decode: one new token given a cache of seq_len
    return {"tokens": sds((B,), tok)}
