"""Zamba2 hybrid: Mamba2 backbone + one *shared* attention block.

54 mamba layers organised as 9 supergroups of 6; the shared attention block
(single weight copy) runs at the top of every supergroup (9 applications).
Each application has its own KV cache slot (activations differ), so the
decode cache is (9, B, T, KvE, dh) — head-sharded exactly like a dense
transformer: the paper's technique applies to the shared block
(DESIGN.md §5 "partial").
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.mamba2 import init_mamba_layer, mamba_block, zero_mamba_state
from repro.models.partitioning import NULL, Partitioner


class Zamba2Model:
    def __init__(self, cfg: ModelConfig, *, tp: int = 1, part: Partitioner = NULL,
                 remat: str = "none", use_kernel: bool = False):
        self.cfg = cfg
        self.part = part
        self.remat = remat
        # Shared-attention decode through the Pallas flash-decode kernel
        # over the identity (dense) grid: the hybrid cache is one shared
        # block per supergroup, so there are no per-layer resident maps.
        self.use_kernel = use_kernel
        self.hd = L.head_dims(cfg, tp)
        assert cfg.shared_attn_every > 0
        assert cfg.n_layers % cfg.shared_attn_every == 0
        self.n_groups = cfg.n_layers // cfg.shared_attn_every  # 9
        self.group = cfg.shared_attn_every                     # 6

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_attn, k_mlp = jax.random.split(key, 4)
        lk = jax.random.split(k_layers, cfg.n_layers)
        lkeys = lk.reshape((self.n_groups, self.group) + lk.shape[1:])
        layers_p = jax.vmap(jax.vmap(lambda k: init_mamba_layer(k, cfg)))(lkeys)
        dt = jnp.dtype(cfg.param_dtype)
        shared = {"attn": L.init_attention(k_attn, cfg, self.hd),
                  "mlp": L.init_mlp(k_mlp, cfg),
                  "ln1": jnp.ones((cfg.d_model,), dt),
                  "ln2": jnp.ones((cfg.d_model,), dt)}
        params = {"layers": layers_p, "shared": shared}
        params.update(L.init_embed(k_emb, cfg))
        params["ln_f"] = jnp.ones((cfg.d_model,), dt)
        return params

    # ----------------------------------------------------------------- body
    def _shared_attn(self, params, x, positions, cache, cache_pos):
        cfg, part = self.cfg, self.part
        p = params["shared"]
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_out, new_cache = L.self_attention_block(
            cfg, p["attn"], self.hd, h, positions, part,
            cache=cache, cache_pos=cache_pos, use_kernel=self.use_kernel)
        x = x + attn_out
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp_block(cfg, p["mlp"], h, part), new_cache

    def _run(self, params, x, positions, state, cache_pos):
        """state: {"attn_cache": stacked(G,...) or None, "mamba": stacked(G,g,...)}"""
        def group_body(carry, xs):
            x = carry
            if self.part.mesh is not None:  # pin per-group slice (no hoist)
                from repro.models.layers import pin_layer_slice
                xs = pin_layer_slice(xs)
            mamba_p, attn_cache, mamba_state = xs
            x, new_attn_cache = self._shared_attn(params, x, positions,
                                                  attn_cache, cache_pos)

            def inner(x, ixs):
                lp, lst = ixs
                out, new_lst = mamba_block(self.cfg, lp, x, lst, self.part)
                return x + out, new_lst

            x, new_mamba = jax.lax.scan(inner, x, (mamba_p, mamba_state))
            return x, (new_attn_cache, new_mamba)

        if self.remat != "none":
            from repro.models.transformer import REMAT_POLICIES
            group_body = jax.checkpoint(group_body,
                                        policy=REMAT_POLICIES[self.remat],
                                        prevent_cse=False)
        xs = (params["layers"], state["attn_cache"], state["mamba"])
        x, (new_cache, new_mamba) = jax.lax.scan(group_body, x, xs)
        return x, {"attn_cache": new_cache, "mamba": new_mamba}

    def _zero_state(self, batch: int, max_seq: int, with_cache: bool):
        cfg = self.cfg
        mamba = zero_mamba_state(cfg, batch, lead=(self.n_groups, self.group))
        attn_cache = None
        if with_cache:
            attn_cache = {
                "k": jnp.zeros((self.n_groups, batch, max_seq, self.hd.KvE,
                                self.hd.dh), jnp.dtype(cfg.dtype)),
                "v": jnp.zeros((self.n_groups, batch, max_seq, self.hd.KvE,
                                self.hd.dh), jnp.dtype(cfg.dtype)),
            }
        return {"attn_cache": attn_cache, "mamba": mamba}

    # --------------------------------------------------------------- forward
    def forward(self, params, tokens, **_):
        cfg, part = self.cfg, self.part
        B, S = tokens.shape
        x = L.embed(cfg, params, tokens, part)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        state = self._zero_state(B, S, with_cache=False)
        x, _ = self._run(params, x, positions, state, None)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return L.unembed(cfg, params, x, part), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits, batch["labels"], self.part)

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, params, batch: int, max_seq: int, **_):
        return {"cache": self._zero_state(batch, max_seq, with_cache=True),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, state, tokens):
        cfg, part = self.cfg, self.part
        B, S = tokens.shape
        x = L.embed(cfg, params, tokens, part)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_state = self._run(params, x, positions, state["cache"],
                                 jnp.zeros((), jnp.int32))
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(cfg, params, x[:, -1:, :], part)
        return logits[:, 0], {"cache": new_state,
                              "pos": jnp.asarray(S, jnp.int32)}

    def decode_step(self, params, state, tokens):
        cfg, part = self.cfg, self.part
        B = tokens.shape[0]
        pos = state["pos"]
        x = L.embed(cfg, params, tokens[:, None], part)
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        x, new_state = self._run(params, x, positions, state["cache"], pos)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(cfg, params, x, part)
        return logits[:, 0], {"cache": new_state, "pos": pos + 1}
