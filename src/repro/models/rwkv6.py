"""RWKV-6 "Finch" — attention-free LM with data-dependent decay.

Faithful structure (arXiv:2404.05892): token-shift ddlerp with a shared
low-rank adapter, per-channel data-dependent decay w = exp(-exp(w0+lora)),
per-head WKV state S in R^{dh x dh}, bonus u, group-norm, silu(g) gating,
squared-relu channel-mix. Decode state is O(1) — the paper's
head+KV-cache partitioning unit does not exist (DESIGN.md §5); the WKV
head-state shards over the model axis instead.

The pure-jnp WKV recurrence here is the oracle; the TPU hot path is the
chunked Pallas kernel in ``repro.kernels.rwkv6_kernel``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.partitioning import NULL, Partitioner

LORA_R = 32      # shared ddlerp adapter rank
LORA_W_R = 64    # decay adapter rank
MIX_NAMES = ("w", "k", "v", "r", "g")


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence (oracle; f32).

    r,k,v,w: (B,S,H,dh); u: (H,dh); state: (B,H,dh,dh) with S[i,j] indexed
    [key_dim i, value_dim j]. Returns y (B,S,H,dh), final state.
    """
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, rkvw):
        r_t, k_t, v_t, w_t = rkvw
        y = jnp.einsum("bhi,bhij->bhj", r_t, S)
        bonus = jnp.einsum("bhi,hi,bhi->bh", r_t, u, k_t)
        y = y + bonus[..., None] * v_t
        S = w_t[..., None] * S + k_t[..., None] * v_t[:, :, None, :]
        return S, y

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def group_norm_heads(y, scale, bias, eps: float = 1e-5):
    """Per-head layer norm of (B,S,H,dh); scale/bias (H*dh,)."""
    B, S, H, dh = y.shape
    y32 = y.astype(jnp.float32)
    mu = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    out = (y32 - mu) * jax.lax.rsqrt(var + eps)
    out = out.reshape(B, S, H * dh) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out


class RWKV6Model:
    def __init__(self, cfg: ModelConfig, *, tp: int = 1, part: Partitioner = NULL,
                 remat: str = "none", use_kernel: bool = False):
        self.cfg = cfg
        self.part = part
        self.remat = remat
        self.use_kernel = use_kernel
        self.H = cfg.n_heads
        self.dh = cfg.d_model // cfg.n_heads

    # ------------------------------------------------------------------ init
    def _init_layer(self, key) -> dict:
        cfg = self.cfg
        D, F = cfg.d_model, cfg.d_ff
        dt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 12)
        p: Dict[str, Any] = {
            # time mix
            "mu_x": jnp.full((D,), 0.5, dt),
            "mix_mu": jnp.full((5, D), 0.5, dt),
            "lora_A": L.dense_init(ks[0], D, (D, 5 * LORA_R), dt),
            "lora_B": L.dense_init(ks[1], LORA_R, (5, LORA_R, D), dt) * 0.0,
            "w0": jnp.full((D,), -6.0, dt),   # exp(-exp(-6)) ~ slow decay
            "lw_A": L.dense_init(ks[2], D, (D, LORA_W_R), dt),
            "lw_B": L.dense_init(ks[3], LORA_W_R, (LORA_W_R, D), dt) * 0.0,
            "wr": L.dense_init(ks[4], D, (D, D), dt),
            "wk": L.dense_init(ks[5], D, (D, D), dt),
            "wv": L.dense_init(ks[6], D, (D, D), dt),
            "wg": L.dense_init(ks[7], D, (D, D), dt),
            "wo": L.dense_init(ks[8], D, (D, D), dt),
            "u": jnp.zeros((self.H, self.dh), dt),
            "gn_scale": jnp.ones((D,), dt),
            "gn_bias": jnp.zeros((D,), dt),
            # channel mix
            "mu_ck": jnp.full((D,), 0.5, dt),
            "mu_cr": jnp.full((D,), 0.5, dt),
            "wck": L.dense_init(ks[9], D, (D, F), dt),
            "wcv": L.dense_init(ks[10], F, (F, D), dt),
            "wcr": L.dense_init(ks[11], D, (D, D), dt),
        }
        for nm in ("ln1", "ln2"):
            p[nm] = jnp.ones((D,), dt)
            p[nm + "_b"] = jnp.zeros((D,), dt)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_f = jax.random.split(key, 3)
        lkeys = jax.random.split(k_layers, cfg.n_layers)
        params = {"layers": jax.vmap(self._init_layer)(lkeys)}
        params.update(L.init_embed(k_emb, cfg))
        params["ln_f"] = jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype))
        params["ln_f_b"] = jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype))
        return params

    # ------------------------------------------------------------- time mix
    def _time_mix(self, p, x, shift_state, wkv_state):
        """x: (B,S,D); shift_state: (B,D) last token of previous chunk.
        Returns (out, new_shift, new_wkv)."""
        cfg, part = self.cfg, self.part
        B, S, D = x.shape
        xprev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
        dx = xprev - x
        x_mix = x + dx * p["mu_x"]
        lora = jnp.tanh(x_mix @ p["lora_A"]).reshape(B, S, 5, LORA_R)
        lora = jnp.einsum("bsnr,nrd->bsnd", lora, p["lora_B"])
        mixed = x[:, :, None, :] + dx[:, :, None, :] * \
            (p["mix_mu"][None, None] + lora)                    # (B,S,5,D)
        xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(5))
        r = xr @ p["wr"]
        k = xk @ p["wk"]
        v = xv @ p["wv"]
        g = xg @ p["wg"]
        w_log = p["w0"].astype(jnp.float32) + \
            (jnp.tanh(xw @ p["lw_A"]) @ p["lw_B"]).astype(jnp.float32)
        w = jnp.exp(-jnp.exp(w_log))                            # (B,S,D) in (0,1)
        hsplit = lambda t: t.reshape(B, S, self.H, self.dh)
        r, k, v, w = hsplit(r), hsplit(k), hsplit(v), hsplit(w)
        r = part.constrain(r, ("batch", "seq", "ssm_heads", None))
        k = part.constrain(k, ("batch", "seq", "ssm_heads", None))
        v = part.constrain(v, ("batch", "seq", "ssm_heads", None))
        if self.use_kernel:
            from repro.kernels import ops as kops
            y, new_wkv = kops.rwkv6(r, k, v, w, p["u"], wkv_state)
        else:
            y, new_wkv = wkv_scan(r, k, v, w, p["u"], wkv_state)
        new_wkv = part.constrain(new_wkv, ("batch", "ssm_heads", None, None))
        y = group_norm_heads(y, p["gn_scale"], p["gn_bias"])
        y = (y * jax.nn.silu(g.reshape(B, S, D).astype(jnp.float32))).astype(x.dtype)
        out = y @ p["wo"]
        return part.constrain(out, ("batch", "res_seq", "d_model")), x[:, -1, :], new_wkv

    def _channel_mix(self, p, x, shift_state):
        part = self.part
        xprev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
        dx = xprev - x
        xk = x + dx * p["mu_ck"]
        xr = x + dx * p["mu_cr"]
        k = jnp.square(jax.nn.relu(xk @ p["wck"]))
        k = part.constrain(k, ("batch", "seq", "d_ff"))
        out = jax.nn.sigmoid(xr @ p["wcr"]) * (k @ p["wcv"])
        return part.constrain(out, ("batch", "res_seq", "d_model")), x[:, -1, :]

    def _layer(self, p, x, state):
        cfg = self.cfg
        h = L.layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
        tm, new_st, new_wkv = self._time_mix(p, h, state["shift_t"], state["wkv"])
        x = x + tm
        h = L.layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
        cm, new_sc = self._channel_mix(p, h, state["shift_c"])
        x = x + cm
        return x, {"shift_t": new_st, "shift_c": new_sc, "wkv": new_wkv}

    # --------------------------------------------------------------- forward
    def _zero_state(self, batch: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        return {
            "shift_t": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
            "shift_c": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
            "wkv": jnp.zeros((cfg.n_layers, batch, self.H, self.dh, self.dh),
                             jnp.float32),
        }

    def _run_layers(self, params, x, state):
        def body(x, xs):
            if self.part.mesh is not None:  # pin per-layer slice (no hoist)
                from repro.models.layers import pin_layer_slice
                xs = pin_layer_slice(xs)
            p, st = xs
            x, new_st = self._layer(p, x, st)
            return x, new_st
        if self.remat != "none":
            from repro.models.transformer import REMAT_POLICIES
            body = jax.checkpoint(body, policy=REMAT_POLICIES[self.remat],
                                  prevent_cse=False)
        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
        return x, new_state

    def forward(self, params, tokens, **_):
        cfg, part = self.cfg, self.part
        x = L.embed(cfg, params, tokens, part)
        state = self._zero_state(tokens.shape[0])
        x, _ = self._run_layers(params, x, state)
        x = L.layer_norm(x, params["ln_f"], params["ln_f_b"], cfg.norm_eps)
        return L.unembed(cfg, params, x, part), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits, batch["labels"], self.part)

    # ---------------------------------------------------------------- decode
    def init_decode_state(self, params, batch: int, max_seq: int, **_):
        return {"cache": self._zero_state(batch), "pos": jnp.zeros((), jnp.int32)}

    def prefill(self, params, state, tokens):
        cfg, part = self.cfg, self.part
        x = L.embed(cfg, params, tokens, part)
        x, new_state = self._run_layers(params, x, state["cache"])
        x = L.layer_norm(x, params["ln_f"], params["ln_f_b"], cfg.norm_eps)
        logits = L.unembed(cfg, params, x[:, -1:, :], part)
        return logits[:, 0], {"cache": new_state,
                              "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def decode_step(self, params, state, tokens):
        cfg, part = self.cfg, self.part
        x = L.embed(cfg, params, tokens[:, None], part)
        x, new_state = self._run_layers(params, x, state["cache"])
        x = L.layer_norm(x, params["ln_f"], params["ln_f_b"], cfg.norm_eps)
        logits = L.unembed(cfg, params, x, part)
        return logits[:, 0], {"cache": new_state, "pos": state["pos"] + 1}
