"""Common neural layers: norms, RoPE, GQA attention (with expanded-KV TP
layout and head padding), SwiGLU/GELU MLPs, embeddings.

All functions are pure; parameters are plain nested dicts of jnp arrays.
Sharding is expressed only through a :class:`~repro.models.partitioning.Partitioner`
so the same code runs unsharded (smoke tests) or on a production mesh.

Head layout for tensor parallelism (DESIGN.md §4):
  Hp  — query heads zero-padded to a multiple of the TP degree,
  KvE — KV heads expanded (zero-pad + nearest-repeat) to ``max(pad(K), tp)``;
        the repeat happens on *activations* so GQA gradients stay exact.
The K/V cache stores the expanded layout: its head axis sharding is identical
to the query-head sharding — the paper's co-location invariant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.partitioning import Partitioner
from repro.models.quantization import wt

# ---------------------------------------------------------------------------
# Derived head dims
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HeadDims:
    H: int      # logical query heads
    K: int      # logical kv heads
    Hp: int     # padded query heads
    Kp: int     # zero-padded kv heads (before repeat)
    rep: int    # activation repeat factor
    KvE: int    # expanded kv heads stored in the cache = Kp * rep
    dh: int

    @property
    def groups(self) -> int:
        return self.Hp // self.KvE


def head_dims(cfg: ModelConfig, tp: int = 1) -> HeadDims:
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if H == 0:
        return HeadDims(0, 0, 0, 0, 1, 0, dh)
    Hp = -(-H // tp) * tp
    if K >= tp:
        Kp = -(-K // tp) * tp
        rep = 1
    else:
        # tp > K: repeat each kv head so every chip holds exactly the KV
        # group(s) its local Q heads attend to.
        Kp = K
        rep = tp // K if tp % K == 0 else tp  # tp%K!=0 never occurs for our archs
    KvE = Kp * rep
    assert Hp % KvE == 0, f"GQA layout mismatch H={H} K={K} tp={tp}"
    return HeadDims(H, K, Hp, Kp, rep, KvE, dh)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, shape: Tuple[int, ...], dtype) -> jnp.ndarray:
    return _normal(key, shape, 1.0 / math.sqrt(d_in), dtype)


def zero_pad_heads(w: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    """Zero-pad a head axis (padded heads never influence outputs: the
    corresponding o-proj rows are zero as well)."""
    pad = to - w.shape[axis]
    if pad == 0:
        return w
    widths = [(0, 0)] * w.ndim
    widths[axis] = (0, pad)
    return jnp.pad(w, widths)


# ---------------------------------------------------------------------------
# Differentiable optimization barrier (layer-slice pinning under scan)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _diff_opt_barrier(flat):
    return jax.lax.optimization_barrier(flat)


def _dob_fwd(flat):
    return jax.lax.optimization_barrier(flat), None


def _dob_bwd(_, g):
    # pin the cotangents too — the backward scan has the same
    # gather-of-slice hoisting exposure on the gradients; float0 /
    # symbolic-zero leaves (int inputs) pass through untouched.
    out = [t if t is None or getattr(t, "dtype", None) == jax.dtypes.float0
           else jax.lax.optimization_barrier(t) for t in g]
    return (out,)


_diff_opt_barrier.defvjp(_dob_fwd, _dob_bwd)


def pin_layer_slice(xs):
    """``jax.lax.optimization_barrier`` over a pytree, usable under
    ``jax.grad``: ``optimization_barrier`` has no differentiation rule, so
    training steps that scan over barriered stacked layer params failed to
    trace.  Identity VJP with barriered cotangents keeps the FSDP
    no-hoist property (see TransformerLM._barrier) in both directions."""
    flat, td = jax.tree_util.tree_flatten(xs)
    return jax.tree_util.tree_unflatten(td, _diff_opt_barrier(flat))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: dict, name: str, x):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p[name], p[name + "_b"], cfg.norm_eps)
    return rms_norm(x, p[name], cfg.norm_eps)


def init_norm(cfg: ModelConfig, d: int, dtype) -> dict:
    out = {"": jnp.ones((d,), dtype)}
    if cfg.norm_type == "layernorm":
        out["_b"] = jnp.zeros((d,), dtype)
    return out


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dh_rot: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh_rot, 2, dtype=jnp.float32) / dh_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: (B, S, n_heads, dh); positions: (B, S) int32. Rotates the first
    ``fraction`` of the head dim (GLM-4 rotates half)."""
    B, S, N, dh = x.shape
    dh_rot = int(dh * fraction)
    if dh_rot % 2:
        dh_rot -= 1
    freqs = rope_freqs(dh_rot, theta)                       # (dh_rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh_rot/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr = x[..., :dh_rot].astype(jnp.float32)
    x1, x2 = xr[..., : dh_rot // 2], xr[..., dh_rot // 2:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., dh_rot:]], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Attention (GQA, causal / sliding window / cross, cache-aware)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, hd: HeadDims, *, cross: bool = False) -> dict:
    D, dtype = cfg.d_model, jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": zero_pad_heads(dense_init(ks[0], D, (D, hd.H, hd.dh), dtype), 1, hd.Hp),
        "wk": zero_pad_heads(dense_init(ks[1], D, (D, hd.K, hd.dh), dtype), 1, hd.Kp),
        "wv": zero_pad_heads(dense_init(ks[2], D, (D, hd.K, hd.dh), dtype), 1, hd.Kp),
        "wo": zero_pad_heads(dense_init(ks[3], hd.H * hd.dh, (hd.H, hd.dh, D), dtype), 0, hd.Hp),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hd.Hp, hd.dh), dtype)
        p["bk"] = jnp.zeros((hd.Kp, hd.dh), dtype)
        p["bv"] = jnp.zeros((hd.Kp, hd.dh), dtype)
    if cross:
        # gated cross-attention (llama-3.2-vision style)
        p["gate"] = jnp.zeros((), dtype)
    return p


def qkv_project(cfg: ModelConfig, p: dict, hd: HeadDims, x, kv_x,
                positions, kv_positions, part: Partitioner,
                rope: bool = True):
    """Returns q (B,S,Hp,dh) and expanded k, v (B,T,KvE,dh)."""
    q = jnp.einsum("bsd,dhk->bshk", x, wt(p, "wq", x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, wt(p, "wk", x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, wt(p, "wv", x.dtype))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, kv_positions, cfg.rope_theta, cfg.rope_fraction)
    if hd.rep > 1:  # expand on activations => exact GQA gradients
        k = jnp.repeat(k, hd.rep, axis=2)
        v = jnp.repeat(v, hd.rep, axis=2)
    q = part.constrain(q, ("batch", "seq", "heads", None))
    k = part.constrain(k, ("batch", "seq", "kv_heads", None))
    v = part.constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attention_scores(q, k, v, mask, part: Partitioner):
    """q: (B,S,Hp,dh), k/v: (B,T,KvE,dh), mask: broadcastable to (B,1,1,S,T)
    or None. Returns (B,S,Hp,dh). Softmax in f32."""
    B, S, Hp, dh = q.shape
    T, KvE = k.shape[1], k.shape[2]
    G = Hp // KvE
    qg = q.reshape(B, S, KvE, G, dh)
    scores = jnp.einsum("bsegd,bted->begst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("begst,bted->bsegd", probs.astype(v.dtype), v)
    out = out.reshape(B, S, Hp, dh)
    return part.constrain(out, ("batch", "seq", "heads", None))


def chunked_attention(q, k, v, q_positions, kv_positions, part: Partitioner,
                      *, causal: bool = True, window: int = 0,
                      chunk: int = 1024, kv_valid=None):
    """Flash-style attention in pure XLA: lax.scan over KV chunks with
    online-softmax running (m, l, acc) — peak memory O(S·chunk) instead of
    O(S²).  This is the memory-sane formulation every production system
    uses for long-sequence prefill/training; the Pallas kernel is its TPU
    twin (kernels/flash_attention.py).

    q: (B,S,Hp,dh); k/v: (B,T,KvE,dh); positions (B,S)/(B,T);
    kv_valid: optional scalar count of valid cache entries.
    Returns (B,S,Hp,dh) in q.dtype.
    """
    B, S, Hp, dh = q.shape
    T, KvE = k.shape[1], k.shape[2]
    G = Hp // KvE
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nk = T // chunk
    scale = 1.0 / math.sqrt(dh)
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, KvE, G, dh)
    kc = k.reshape(B, nk, chunk, KvE, dh)
    vc = v.reshape(B, nk, chunk, KvE, dh)
    pc = kv_positions.reshape(B, nk, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs                                  # (B,chunk,KvE,dh)
        s = jnp.einsum("bsegd,bted->begst", qg, kb.astype(jnp.float32))
        pred = jnp.ones((B, S, chunk), jnp.bool_)
        if causal:
            pred = pb[:, None, :] <= q_positions[:, :, None]
            if window > 0:
                pred &= pb[:, None, :] > (q_positions[:, :, None] - window)
        if kv_valid is not None:
            pred &= (pb < kv_valid)[:, None, :]
        s = jnp.where(pred[:, None, None], s, -1e30)   # (B,1,1,S,chunk)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + \
            jnp.einsum("begst,bted->begsd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, KvE, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KvE, G, S), jnp.float32)
    a0 = jnp.zeros((B, KvE, G, S, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, Hp, dh).astype(q.dtype)
    return part.constrain(out, ("batch", "seq", "heads", None))


def causal_mask(q_positions, kv_positions, window: int = 0):
    """(B,1,1,S,T) boolean; True = attend. window=0 means full causal."""
    m = kv_positions[:, None, :] <= q_positions[:, :, None]
    if window > 0:
        m &= kv_positions[:, None, :] > (q_positions[:, :, None] - window)
    return m[:, None, None, :, :]


def _decode_lengths(cache_pos, B: int):
    """Valid-cache-length vector for the flash-decode kernel: the current
    token writes at ``cache_pos`` and attends positions <= its own, so the
    kernel's per-row length is ``pos + 1`` (scalar positions broadcast —
    lock-step batches share one depth)."""
    cp = jnp.asarray(cache_pos, jnp.int32)
    if cp.ndim == 0:
        cp = jnp.broadcast_to(cp, (B,))
    return cp + 1


def _head_rows_or_identity(head_rows, head_inv, n_rows: int):
    """Gather/scatter maps for the resident-slice kernel; identity (dense
    grid over all rows, no scatter) when no placement maps are threaded."""
    if head_rows is None:
        return jnp.arange(n_rows, dtype=jnp.int32), None
    return head_rows, head_inv


def _decode_kernel_ok(T: int) -> bool:
    """The flash-decode kernel streams the cache in ``bk``-sized blocks;
    a cache extent that does not tile (T > bk and T % bk != 0 — e.g. the
    1601-token VLM image stub) keeps the jnp path."""
    from repro.kernels.decode_attention import DEFAULT_BK
    return T % min(DEFAULT_BK, T) == 0


def _q8(t):
    """Per-(token, head) int8 quantization over dh: (values int8, scales
    f32) — one definition shared by the dense and paged int8 cache
    branches so their stored values cannot diverge."""
    sc = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)),
                             axis=-1), 1e-8) / 127.0
    qq = jnp.clip(jnp.round(t.astype(jnp.float32) / sc[..., None]),
                  -127, 127).astype(jnp.int8)
    return qq, sc.astype(jnp.float32)


def _project_out(p: dict, out, part: Partitioner, *, gate=None):
    """Shared attention output tail: wo projection (plus the VLM
    cross-attention gate when given), constrained to the residual layout
    — one definition so the kernel and jnp branches cannot diverge."""
    out = jnp.einsum("bshk,hkd->bsd", out, wt(p, "wo", out.dtype))
    if gate is not None:
        out = out * jnp.tanh(gate).astype(out.dtype)
    return part.constrain(out, ("batch", "res_seq", "d_model"))


def self_attention_block(cfg: ModelConfig, p: dict, hd: HeadDims, x,
                         positions, part: Partitioner, *,
                         cache=None, cache_pos=None, window: int = 0,
                         use_kernel: bool = False, head_rows=None,
                         head_inv=None, page_map=None, write_valid=None):
    """Causal self-attention with optional KV cache.

    cache: dict {"k","v"[, "pos"]} of (B, cache_len, KvE, dh) buffers.
      - linear cache (cache_len == max_seq): new K/V written at ``cache_pos``;
      - ring cache (sliding window, cache_len == window, decode S=1): slot
        ``cache_pos % window``; "pos" (window,) holds absolute positions
        (init to a large negative so empty slots never pass the mask).
      - paged cache (``page_map`` is not None): cache buffers are pooled
        pages (n_pages, P, KvE, dh) shared by all slots; ``page_map``
        (B, np) int32 maps row b's logical page i to a physical page id
        (-1 = unmapped: writes there DROP, reads clamp to page 0 and are
        hidden by the causal mask).  ``write_valid`` (B, S) bool masks
        which of this call's tokens actually store K/V (chunked prefill
        tails) — attention itself is masked by positions as usual.
    cache_pos: absolute position of the first query token — a scalar int32,
      or a (B,) int32 vector for slot-level continuous batching (linear
      cache, S == 1 only): row b writes its new K/V at its own position
      ``cache_pos[b]`` and the causal mask is taken per row, so slots at
      different sequence depths decode in one batch.
    use_kernel: S == 1 linear-cache decode dispatches to the Pallas
      flash-decode kernel (``ops.decode_attention_resident_bshd``; the
      int8 cache uses the fused int8 variant) instead of the jnp path.
      ``head_rows``/``head_inv`` are that kernel's per-layer gather/
      scatter maps — the PHYSICAL q-head rows in slot-grouped placement
      order (``placement_bridge.head_row_maps``); None runs the identity
      (dense) grid.  Ring caches and windowed attention keep the jnp path
      (their validity set is not a prefix).
    Returns (out, new_cache).
    """
    B, S = x.shape[0], x.shape[1]
    q, k, v = qkv_project(cfg, p, hd, x, x, positions, positions, part)

    def attend(kk, vv, kv_pos, mask):
        """Chunked (flash-style) when the KV extent is long, else vanilla."""
        T = kk.shape[1]
        ch = 1024
        if S > 1 and T >= 2048 and T % ch == 0:
            return chunked_attention(q, kk, vv, positions, kv_pos, part,
                                     causal=True, window=window, chunk=ch)
        return attention_scores(q, kk, vv, mask, part)

    new_cache = None
    if cache is not None and page_map is not None:
        # ---- paged cache: pooled pages + per-row page table -----------
        n_pages, P = cache["k"].shape[0], cache["k"].shape[1]
        np_log = page_map.shape[1]
        Tmax = np_log * P
        pos32 = positions.astype(jnp.int32)                       # (B, S)
        lpage = jnp.clip(pos32 // P, 0, np_log - 1)
        phys = jnp.take_along_axis(page_map, lpage, axis=1)       # (B, S)
        # unmapped/invalid writes go to a POSITIVE out-of-bounds index so
        # mode="drop" drops them (-1 would wrap to the last page slot)
        oob = jnp.int32(n_pages * P)
        w_idx = jnp.where(phys >= 0, phys * P + pos32 % P, oob)
        if write_valid is not None:
            w_idx = jnp.where(write_valid, w_idx, oob)
        w_flat = w_idx.reshape(B * S)
        gmap = jnp.maximum(page_map, 0)                           # (B, np)
        g_idx = (gmap[:, :, None] * P
                 + jnp.arange(P, dtype=jnp.int32)[None, None, :]
                 ).reshape(B, Tmax)
        # pages sit in the table in LOGICAL order, so the gathered view
        # is position-ordered and the standard causal mask applies
        kv_pos = jnp.broadcast_to(
            jnp.arange(Tmax, dtype=jnp.int32)[None, :], (B, Tmax))

        def scatter(buf, new):
            flat = buf.reshape((n_pages * P,) + buf.shape[2:])
            flat = flat.at[w_flat].set(
                new.reshape((B * S,) + new.shape[2:]), mode="drop")
            return flat.reshape(buf.shape)

        def gather(buf):
            flat = buf.reshape((n_pages * P,) + buf.shape[2:])
            return jnp.take(flat, g_idx, axis=0)          # (B, Tmax, ...)

        rows_m = inv = None
        if use_kernel and S == 1 and cache_pos is not None:
            rows_m, inv = _head_rows_or_identity(head_rows, head_inv,
                                                 q.shape[2])
        if "k_sc" in cache:
            kq, ksc = _q8(k)
            vq, vsc = _q8(v)
            ck, cv = scatter(cache["k"], kq), scatter(cache["v"], vq)
            cks = scatter(cache["k_sc"], ksc)
            cvs = scatter(cache["v_sc"], vsc)
            ck = part.constrain(ck, (None, None, "kv_heads", None))
            cv = part.constrain(cv, (None, None, "kv_heads", None))
            new_cache = dict(cache, k=ck, v=cv, k_sc=cks, v_sc=cvs)
            if rows_m is not None:
                from repro.kernels import ops
                out = ops.decode_attention_int8_paged_bshd(
                    q, ck, cks, cv, cvs, _decode_lengths(cache_pos, B),
                    gmap, rows_m, inv_rows=inv)
                return _project_out(p, out, part), new_cache
            kd = (gather(ck).astype(jnp.float32)
                  * gather(cks)[..., None]).astype(x.dtype)
            vd = (gather(cv).astype(jnp.float32)
                  * gather(cvs)[..., None]).astype(x.dtype)
            mask = causal_mask(positions, kv_pos, 0)
            out = attend(kd, vd, kv_pos, mask)
            return _project_out(p, out, part), new_cache
        ck, cv = scatter(cache["k"], k), scatter(cache["v"], v)
        ck = part.constrain(ck, (None, None, "kv_heads", None))
        cv = part.constrain(cv, (None, None, "kv_heads", None))
        new_cache = dict(cache, k=ck, v=cv)
        if rows_m is not None:
            from repro.kernels import ops
            out = ops.decode_attention_paged_bshd(
                q, ck, cv, _decode_lengths(cache_pos, B), gmap, rows_m,
                inv_rows=inv)
            return _project_out(p, out, part), new_cache
        mask = causal_mask(positions, kv_pos, 0)
        out = attend(gather(ck), gather(cv), kv_pos, mask)
        return _project_out(p, out, part), new_cache
    if cache is not None:
        cache_len = cache["k"].shape[1]
        ring = window > 0 and cache_len == window
        if ring and S > 1:
            # Sliding-window prefill: attend on the full in-flight K/V (the
            # window mask hides everything older), then fold the last
            # ``window`` tokens into the ring buffer (slot t%window <- pos t).
            mask = causal_mask(positions, positions, window)
            out = attend(k, v, positions, mask)
            out = _project_out(p, out, part)
            if S >= window:
                tail_k, tail_v = k[:, -window:], v[:, -window:]
                tail_pos = positions[0, -window:].astype(jnp.int32)
            else:
                pad = window - S
                tail_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                tail_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                tail_pos = jnp.concatenate(
                    [positions[0].astype(jnp.int32),
                     jnp.full((pad,), -2**30, jnp.int32)])
            shift = tail_pos[0] % window
            ck = jnp.roll(tail_k, shift, axis=1)
            cv = jnp.roll(tail_v, shift, axis=1)
            slot_pos = jnp.roll(tail_pos, shift)
            ck = part.constrain(ck, ("batch", "cache_seq", "kv_heads", None))
            cv = part.constrain(cv, ("batch", "cache_seq", "kv_heads", None))
            new_cache = dict(cache, k=ck, v=cv, pos=slot_pos)
            return out, new_cache
        if ring:
            idx = jnp.asarray(cache_pos, jnp.int32) % window
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
            slot_pos = jax.lax.dynamic_update_slice(
                cache["pos"], jnp.reshape(cache_pos, (1,)).astype(jnp.int32), (idx,))
            kv_pos = jnp.broadcast_to(slot_pos[None, :], (B, window))
        elif "k_sc" in cache:
            # int8 KV cache: quantize the new tokens per (token, head) over
            # dh, update values+scales, dequantize for the attention read
            kq, ksc = _q8(k)
            vq, vsc = _q8(v)
            if getattr(cache_pos, "ndim", 0) == 1:
                # per-slot write (continuous batching, S == 1): row b's
                # quantized K/V and scales land at its own position, same
                # drop-at-the-edge rule as the fp per-slot branch below
                rows_b = jnp.arange(B)
                cp = jnp.asarray(cache_pos, jnp.int32)
                ck = cache["k"].at[rows_b, cp].set(kq[:, 0], mode="drop")
                cv = cache["v"].at[rows_b, cp].set(vq[:, 0], mode="drop")
                cks = cache["k_sc"].at[rows_b, cp].set(ksc[:, 0],
                                                       mode="drop")
                cvs = cache["v_sc"].at[rows_b, cp].set(vsc[:, 0],
                                                       mode="drop")
            else:
                ck = jax.lax.dynamic_update_slice(cache["k"], kq,
                                                  (0, cache_pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], vq,
                                                  (0, cache_pos, 0, 0))
                cks = jax.lax.dynamic_update_slice(cache["k_sc"], ksc,
                                                   (0, cache_pos, 0))
                cvs = jax.lax.dynamic_update_slice(cache["v_sc"], vsc,
                                                   (0, cache_pos, 0))
            ck = part.constrain(ck, ("batch", "cache_seq", "kv_heads", None))
            cv = part.constrain(cv, ("batch", "cache_seq", "kv_heads", None))
            new_cache = dict(cache, k=ck, v=cv, k_sc=cks, v_sc=cvs)
            if use_kernel and S == 1 and window == 0 \
                    and _decode_kernel_ok(cache_len):
                from repro.kernels import ops
                rows, inv = _head_rows_or_identity(head_rows, head_inv,
                                                   q.shape[2])
                out = ops.decode_attention_int8_resident_bshd(
                    q, ck, cks, cv, cvs, _decode_lengths(cache_pos, B),
                    rows, inv_rows=inv)
                return _project_out(p, out, part), new_cache
            kv_pos = jnp.broadcast_to(
                jnp.arange(cache_len, dtype=jnp.int32)[None, :], (B, cache_len))
            kd = (ck.astype(jnp.float32) * cks[..., None]).astype(x.dtype)
            vd = (cv.astype(jnp.float32) * cvs[..., None]).astype(x.dtype)
            mask = causal_mask(positions, kv_pos, window)
            out = attend(kd, vd, kv_pos, mask)
            return _project_out(p, out, part), new_cache
        elif getattr(cache_pos, "ndim", 0) == 1:
            # per-slot linear cache write (continuous batching, S == 1):
            # scatter row b's new K/V to its own position. Out-of-range
            # positions (retired slots past cache_len) are dropped.
            rows = jnp.arange(B)
            cp = jnp.asarray(cache_pos, jnp.int32)
            ck = cache["k"].at[rows, cp].set(k[:, 0], mode="drop")
            cv = cache["v"].at[rows, cp].set(v[:, 0], mode="drop")
            slot_pos = None
            kv_pos = jnp.broadcast_to(
                jnp.arange(cache_len, dtype=jnp.int32)[None, :], (B, cache_len))
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_pos, 0, 0))
            slot_pos = None
            kv_pos = jnp.broadcast_to(
                jnp.arange(cache_len, dtype=jnp.int32)[None, :], (B, cache_len))
        ck = part.constrain(ck, ("batch", "cache_seq", "kv_heads", None))
        cv = part.constrain(cv, ("batch", "cache_seq", "kv_heads", None))
        new_cache = dict(cache, k=ck, v=cv)
        if slot_pos is not None:
            new_cache["pos"] = slot_pos
        if use_kernel and S == 1 and ring and _decode_kernel_ok(window):
            # ring-cache decode hot path: same resident gather maps, the
            # window mask consults the ring's position stream instead of
            # rotating the buffer (PR 4's logged kernel-path hole)
            from repro.kernels import ops
            rows, inv = _head_rows_or_identity(head_rows, head_inv,
                                               q.shape[2])
            out = ops.decode_attention_ring_bshd(
                q, ck, cv, _decode_lengths(cache_pos, B), slot_pos,
                window=window, rows=rows, inv_rows=inv)
            return _project_out(p, out, part), new_cache
        if use_kernel and S == 1 and window == 0 and slot_pos is None \
                and _decode_kernel_ok(cache_len):
            # linear-cache decode hot path: the Pallas flash-decode kernel
            # over this dispatch's resident head rows (identity = all)
            from repro.kernels import ops
            rows, inv = _head_rows_or_identity(head_rows, head_inv,
                                               q.shape[2])
            out = ops.decode_attention_resident_bshd(
                q, ck, cv, _decode_lengths(cache_pos, B), rows,
                inv_rows=inv)
            return _project_out(p, out, part), new_cache
        mask = causal_mask(positions, kv_pos, window)
        out = attend(ck, cv, kv_pos, mask)
    else:
        mask = causal_mask(positions, positions, window)
        out = attend(k, v, positions, mask)
    return _project_out(p, out, part), new_cache


def cross_attention_block(cfg: ModelConfig, p: dict, hd: HeadDims, x,
                          part: Partitioner, *, kv_embeds=None, kv_cache=None,
                          kv_mask=None, use_kernel: bool = False):
    """Gated cross-attention (llama-3.2-vision).  K/V come either from
    ``kv_embeds`` (B, n_img, D) — projected here and returned as a static
    cache — or from a previously computed ``kv_cache`` {"k","v"}.

    ``use_kernel`` dispatches S == 1 decode to the flash-decode kernel
    with per-row lengths = ``kv_mask.sum(-1)``: the serving engine's image
    buffers are right-padded (valid rows form a prefix), which is exactly
    the kernel's length-masked validity model.  A traced ``kv_mask`` (any
    jitted caller, including the engine) bypasses the eager prefix check
    below, so jitted callers MUST guarantee right-padded masks by
    construction — the engine does.  Fully masked rows are patched to the
    jnp path's value (uniform average of V) so streams match even with a
    trained, nonzero gate."""
    B, S = x.shape[0], x.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, wt(p, "wq", x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"]
    if kv_cache is None:
        k = jnp.einsum("bsd,dhk->bshk", kv_embeds, wt(p, "wk", x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv_embeds, wt(p, "wv", x.dtype))
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        if hd.rep > 1:
            k = jnp.repeat(k, hd.rep, axis=2)
            v = jnp.repeat(v, hd.rep, axis=2)
        k = part.constrain(k, ("batch", "img_seq", "kv_heads", None))
        v = part.constrain(v, ("batch", "img_seq", "kv_heads", None))
        kv_cache = {"k": k, "v": v}
    k, v = kv_cache["k"], kv_cache["v"]
    if use_kernel and S == 1 and _decode_kernel_ok(k.shape[1]):
        from repro.kernels import ops
        I = k.shape[1]
        if kv_mask is None:
            lens = jnp.full((B,), I, jnp.int32)
        else:
            lens = jnp.sum(kv_mask, axis=-1).astype(jnp.int32)
            if not isinstance(kv_mask, jax.core.Tracer):
                # The kernel models validity as a per-row length, so a
                # concrete mask must be prefix-contiguous (right-padded);
                # a scattered mask would silently attend to wrong slots.
                pref = jnp.arange(I, dtype=jnp.int32)[None, :] < lens[:, None]
                if not bool(jnp.all(jnp.asarray(kv_mask, bool) == pref)):
                    raise ValueError(
                        "use_kernel cross-attention needs a prefix "
                        "(right-padded) kv_mask; got a non-contiguous "
                        "validity set — use the jnp path instead")
        rows = jnp.arange(q.shape[2], dtype=jnp.int32)
        out = ops.decode_attention_resident_bshd(q, k, v, lens, rows)
        if kv_mask is not None:
            # Fully-masked rows: the kernel's length model yields 0, but
            # the jnp path softmaxes a uniformly -1e30 score row into the
            # uniform average of V — match it so use_kernel streams stay
            # equal even with a trained (nonzero) gate.
            G = q.shape[2] // v.shape[2]
            vm = jnp.repeat(jnp.mean(v, axis=1), G, axis=1)[:, None]
            out = jnp.where((lens == 0)[:, None, None, None],
                            vm.astype(out.dtype), out)
        return _project_out(p, out, part, gate=p["gate"]), kv_cache
    mask = None if kv_mask is None else kv_mask[:, None, None, None, :]
    out = attention_scores(q, k, v, mask, part)
    return _project_out(p, out, part, gate=p["gate"]), kv_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, dtype=None) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], D, (D, F), dtype),
            "w_up": dense_init(ks[1], D, (D, F), dtype),
            "w_down": dense_init(ks[2], F, (F, D), dtype),
        }
    return {  # gelu
        "w_up": dense_init(ks[0], D, (D, F), dtype),
        "b_up": jnp.zeros((F,), dtype),
        "w_down": dense_init(ks[1], F, (F, D), dtype),
        "b_down": jnp.zeros((D,), dtype),
    }


def mlp_block(cfg: ModelConfig, p: dict, x, part: Partitioner):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ wt(p, "w_gate", x.dtype)) * (x @ wt(p, "w_up", x.dtype))
        h = part.constrain(h, ("batch", "seq", "d_ff"))
        out = h @ wt(p, "w_down", x.dtype)
    else:
        h = jax.nn.gelu(x @ wt(p, "w_up", x.dtype) + p["b_up"])
        h = part.constrain(h, ("batch", "seq", "d_ff"))
        out = h @ wt(p, "w_down", x.dtype) + p["b_down"]
    return part.constrain(out, ("batch", "res_seq", "d_model"))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    p = {"tok_embed": _normal(ks[0], (cfg.vocab_size, cfg.d_model), 0.02, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed(cfg: ModelConfig, p: dict, tokens, part: Partitioner):
    from repro.models.quantization import is_quantized
    tab = p["tok_embed"]
    if is_quantized(tab):
        # gather int8 rows, dequant the gathered rows only
        rows = jnp.take(tab["q8"], tokens, axis=0).astype(jnp.float32)
        x = (rows * tab["sc"]).astype(jnp.dtype(cfg.dtype))
        return part.constrain(x, ("batch", "res_seq", "d_model"))
    x = jnp.take(tab, tokens, axis=0)
    return part.constrain(x, ("batch", "res_seq", "d_model"))


def unembed(cfg: ModelConfig, p: dict, x, part: Partitioner):
    if cfg.tie_embeddings:
        w = wt(p, "tok_embed", x.dtype).T
    else:
        w = wt(p, "lm_head", x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    return part.constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits, labels, part: Partitioner):
    """Mean token cross-entropy; logits f32 (B,S,V), labels int (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
