"""Decoder-only transformer LM (dense / MoE / VLM / audio families).

- ``lax.scan`` over stacked layer parameters (compile time & HLO size stay
  O(1) in depth; required for the 80-layer dry-runs).
- KV caches are stacked (L, B, T, KvE, dh) pytrees threaded through the layer
  scan as xs/ys; sliding-window archs (Mixtral) use ring-buffer caches of
  length ``window``.
- VLM (llama-3.2-vision): 40 layers = 8 supergroups of [3 self, 1 cross,
  1 self]; cross-attention K/V are projected once from the (stubbed) image
  embeddings and live in the decode state.
- Optional remat (``jax.checkpoint``) around each layer for training.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import init_moe, moe_block, moe_block_capacity
from repro.models.partitioning import NULL, Partitioner

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}

# decay of the router-load EWMA kept in the decode state ("expert_load"):
# load_t = d*load_{t-1} + (1-d)*freq_t.  The serving engine normalizes and
# feeds it to the controller's expert cost model each interval.
EXPERT_LOAD_EWMA = 0.9


class TransformerLM:
    """Config-driven decoder-only LM."""

    def __init__(self, cfg: ModelConfig, *, tp: int = 1,
                 part: Partitioner = NULL, remat: str = "none",
                 capacity_moe: bool = False, capacity_factor: float = 1.25,
                 use_kernel: bool = False):
        self.cfg = cfg
        self.tp = tp
        self.part = part
        self.hd = L.head_dims(cfg, tp)
        self.remat = remat
        # decode attention via the Pallas flash-decode kernel; the decode
        # state may carry per-layer "head_rows"/"head_inv" gather maps
        # (placement_bridge.head_row_maps) so each layer's kernel grid is
        # the slot-grouped resident slice the controller placed.
        self.use_kernel = use_kernel
        self.capacity_moe = capacity_moe
        self.capacity_factor = capacity_factor
        self.is_vlm = cfg.family == "vlm"
        if self.is_vlm:
            assert cfg.n_layers % 5 == 0
            self.n_groups = cfg.n_layers // 5
        self.window = cfg.sliding_window

    # ------------------------------------------------------------------ init
    def _init_layer(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        p = {"attn": L.init_attention(ks[0], cfg, self.hd)}
        dt = jnp.dtype(cfg.param_dtype)
        for nm in ("ln1", "ln2"):
            base = L.init_norm(cfg, cfg.d_model, dt)
            p[nm] = base[""]
            if "_b" in base:
                p[nm + "_b"] = base["_b"]
        if cfg.is_moe:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg)
        return p

    def _init_cross_layer(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 2)
        dt = jnp.dtype(cfg.param_dtype)
        p = {"attn": L.init_attention(ks[0], cfg, self.hd, cross=True),
             "mlp": L.init_mlp(ks[1], cfg),
             "gate_ffn": jnp.zeros((), dt)}
        for nm in ("ln1", "ln2"):
            base = L.init_norm(cfg, cfg.d_model, dt)
            p[nm] = base[""]
            if "_b" in base:
                p[nm + "_b"] = base["_b"]
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_cross, k_f = jax.random.split(key, 4)
        if self.is_vlm:
            sk = jax.random.split(k_layers, 4 * self.n_groups)
            self_keys = sk.reshape((self.n_groups, 4) + sk.shape[1:])
            cross_keys = jax.random.split(k_cross, self.n_groups)
            layers_p = jax.vmap(jax.vmap(self._init_layer))(self_keys)
            cross_p = jax.vmap(self._init_cross_layer)(cross_keys)
            params = {"layers": layers_p, "cross_layers": cross_p}
        else:
            lkeys = jax.random.split(k_layers, cfg.n_layers)
            params = {"layers": jax.vmap(self._init_layer)(lkeys)}
        params.update(L.init_embed(k_emb, cfg))
        fin = L.init_norm(cfg, cfg.d_model, jnp.dtype(cfg.param_dtype))
        params["ln_f"] = fin[""]
        if "_b" in fin:
            params["ln_f_b"] = fin["_b"]
        return params

    def _barrier(self, xs):
        """Pin the per-layer param slice inside the scan body: stops XLA
        from rewriting gather(slice(params,i)) into slice(gather(params))
        and hoisting the FSDP all-gather of the whole stacked layer pytree
        out of the while loop (which materializes all layers' gathered
        weights at once — DESIGN.md §9 / §Perf).  Differentiable (identity
        VJP, layers.pin_layer_slice) so train steps can grad through it."""
        if self.part.mesh is None:
            return xs
        return L.pin_layer_slice(xs)

    # ----------------------------------------------------------------- layer
    def _layer(self, p: dict, x, positions, cache, cache_pos,
               head_rows=None, head_inv=None, page_map=None,
               write_valid=None):
        cfg, part = self.cfg, self.part
        h = L.apply_norm(cfg, p, "ln1", x)
        # explicit SP->TP boundary ON THE BF16 TENSOR: norms run in the
        # sequence-sharded region (pointwise over D), the all-gather happens
        # here rather than on an f32 intermediate chosen by GSPMD
        # (EXPERIMENTS.md §Perf H2-1: halves boundary collective bytes and
        # avoids SPMD "involuntary full rematerialization" reshards).
        h = part.constrain(h, ("batch", "seq", "d_model"))
        attn_out, new_cache = L.self_attention_block(
            cfg, p["attn"], self.hd, h, positions, part,
            cache=cache, cache_pos=cache_pos, window=self.window,
            use_kernel=self.use_kernel, head_rows=head_rows,
            head_inv=head_inv, page_map=page_map, write_valid=write_valid)
        x = x + attn_out
        h = L.apply_norm(cfg, p, "ln2", x)
        h = part.constrain(h, ("batch", "seq", "d_model"))
        aux = jnp.zeros((), jnp.float32)
        freq = None
        if cfg.is_moe:
            if self.capacity_moe:
                mlp_out, aux, freq = moe_block_capacity(
                    cfg, p["moe"], h, part, self.capacity_factor)
            else:
                mlp_out, aux, freq = moe_block(cfg, p["moe"], h, part)
        else:
            mlp_out = L.mlp_block(cfg, p["mlp"], h, part)
        return x + mlp_out, new_cache, aux, freq

    def _cross_layer(self, p: dict, x, img_kv, img_mask):
        cfg, part = self.cfg, self.part
        h = L.apply_norm(cfg, p, "ln1", x)
        attn_out, _ = L.cross_attention_block(cfg, p["attn"], self.hd, h, part,
                                              kv_cache=img_kv, kv_mask=img_mask,
                                              use_kernel=self.use_kernel)
        x = x + attn_out
        h = L.apply_norm(cfg, p, "ln2", x)
        mlp_out = L.mlp_block(cfg, p["mlp"], h, part)
        return x + mlp_out * jnp.tanh(p["gate_ffn"]).astype(x.dtype)

    def _project_img_kv(self, params, img_embeds):
        """vmap K/V projection over the 8 cross layers -> (G,B,I,KvE,dh)."""
        def proj(p):
            from repro.models.quantization import wt
            k = jnp.einsum("bsd,dhk->bshk", img_embeds,
                           wt(p["attn"], "wk", img_embeds.dtype))
            v = jnp.einsum("bsd,dhk->bshk", img_embeds,
                           wt(p["attn"], "wv", img_embeds.dtype))
            if self.cfg.qkv_bias:
                k, v = k + p["attn"]["bk"], v + p["attn"]["bv"]
            if self.hd.rep > 1:
                k = jnp.repeat(k, self.hd.rep, axis=2)
                v = jnp.repeat(v, self.hd.rep, axis=2)
            return {"k": k, "v": v}
        return jax.vmap(proj)(params["cross_layers"])

    # --------------------------------------------------------------- forward
    def _run_layers(self, params, x, positions, cache, cache_pos,
                    img_kv=None, img_mask=None, head_rows=None,
                    head_inv=None, page_map=None, write_valid=None):
        """Scan over layers. cache: stacked {"k","v"[,"pos"]} or None.
        ``head_rows``/``head_inv``: stacked (n_layers, Hp) kernel gather/
        scatter maps scanned alongside the cache, so layer l's decode
        dispatch reads layer l's resident-slice row map (dense archs only
        — VLM caches are (G, 4, ...) stacks whose migrations are
        all-layers-equal, so identity maps stay correct there).
        ``page_map``/``write_valid`` (paged caches) are CLOSURES over the
        scan, not scanned: one page table serves every layer — the layer
        axis lives in the page store, not the table."""
        remat_policy = REMAT_POLICIES[self.remat]

        def body(carry, xs):
            x, aux = carry
            xs = self._barrier(xs)
            if self.is_vlm:
                (self_p, cross_p, kv) = xs
                for i in range(3):
                    sp = jax.tree.map(lambda a, i=i: a[i], self_p)
                    x, _, a, _ = self._layer(sp, x, positions, None, cache_pos)
                    aux += a
                x = self._cross_layer(cross_p, x, kv, img_mask)
                sp = jax.tree.map(lambda a: a[3], self_p)
                x, _, a, _ = self._layer(sp, x, positions, None, cache_pos)
                return (x, aux + a), None
            layer_p, layer_cache, rows, inv = xs
            x, new_cache, a, f = self._layer(layer_p, x, positions,
                                             layer_cache, cache_pos, rows,
                                             inv, page_map=page_map,
                                             write_valid=write_valid)
            if self.cfg.is_moe:
                return (x, aux + a), (new_cache, f)
            return (x, aux + a), new_cache

        if self.remat != "none":
            body = jax.checkpoint(body, policy=remat_policy,
                                  prevent_cse=False)

        aux0 = jnp.zeros((), jnp.float32)
        if self.is_vlm:
            if cache is not None:
                return self._run_layers_vlm_cached(params, x, positions, cache,
                                                   cache_pos, img_kv, img_mask,
                                                   body)
            xs = (params["layers"], params["cross_layers"], img_kv)
            (x, aux), _ = jax.lax.scan(body, (x, aux0), xs)
            return x, None, aux, None
        xs = (params["layers"], cache, head_rows, head_inv)
        if self.cfg.is_moe:
            # ys carry the per-layer routed-token fractions alongside the
            # cache -> stacked (L, E) router-load observation
            (x, aux), (new_cache, freqs) = jax.lax.scan(body, (x, aux0), xs)
            return x, new_cache, aux, freqs
        (x, aux), new_cache = jax.lax.scan(body, (x, aux0), xs)
        return x, new_cache, aux, None

    def _run_layers_vlm_cached(self, params, x, positions, cache, cache_pos,
                               img_kv, img_mask, _body_unused):
        """VLM with self-attn KV caches: 4 self caches per group."""
        def body(carry, xs):
            x, aux = carry
            xs = self._barrier(xs)
            self_p, cross_p, kv, self_cache = xs
            new_caches = []
            for i in range(3):
                sp = jax.tree.map(lambda a, i=i: a[i], self_p)
                lc = jax.tree.map(lambda a, i=i: a[i], self_cache)
                x, nc, a, _ = self._layer(sp, x, positions, lc, cache_pos)
                new_caches.append(nc)
                aux += a
            x = self._cross_layer(cross_p, x, kv, img_mask)
            sp = jax.tree.map(lambda a: a[3], self_p)
            lc = jax.tree.map(lambda a: a[3], self_cache)
            x, nc, a, _ = self._layer(sp, x, positions, lc, cache_pos)
            new_caches.append(nc)
            stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *new_caches)
            return (x, aux + a), stacked

        xs = (params["layers"], params["cross_layers"], img_kv, cache)
        (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        return x, new_cache, aux, None

    def forward(self, params, tokens, *, img_embeds=None, img_mask=None):
        """Full-sequence forward (training / no-cache prefill). Returns
        (logits, aux_loss)."""
        cfg, part = self.cfg, self.part
        B, S = tokens.shape
        x = L.embed(cfg, params, tokens, part)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        img_kv = None
        if self.is_vlm:
            img_kv = self._project_img_kv(params, img_embeds)
        x, _, aux, _ = self._run_layers(params, x, positions, None, None,
                                        img_kv=img_kv, img_mask=img_mask)
        x = L.apply_norm(cfg, params, "ln_f", x)
        logits = L.unembed(cfg, params, x, part)
        return logits, aux

    def loss(self, params, batch) -> jnp.ndarray:
        logits, aux = self.forward(params, batch["tokens"],
                                   img_embeds=batch.get("img_embeds"),
                                   img_mask=batch.get("img_mask"))
        ce = L.cross_entropy(logits, batch["labels"], self.part)
        return ce + 0.01 * aux

    # ----------------------------------------------------------------- cache
    def cache_len(self, max_seq: int) -> int:
        return min(max_seq, self.window) if self.window else max_seq

    def init_cache(self, batch: int, max_seq: int, dtype=None) -> dict:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        T = self.cache_len(max_seq)
        lead = (self.n_groups, 4) if self.is_vlm else (cfg.n_layers,)
        shape_k = lead + (batch, T, self.hd.KvE, self.hd.dh)
        ring = bool(self.window and T == self.window)
        if cfg.kv_quant and not ring:
            # int8 KV cache with per-(token, head) scales (§Perf): halves
            # the resident cache; dequant happens at the attention read.
            cache = {"k": jnp.zeros(shape_k, jnp.int8),
                     "v": jnp.zeros(shape_k, jnp.int8),
                     "k_sc": jnp.zeros(lead + (batch, T, self.hd.KvE),
                                       jnp.float32),
                     "v_sc": jnp.zeros(lead + (batch, T, self.hd.KvE),
                                       jnp.float32)}
            return cache
        cache = {"k": jnp.zeros(shape_k, dtype), "v": jnp.zeros(shape_k, dtype)}
        if ring:
            cache["pos"] = jnp.full(lead + (T,), jnp.int32(-2**30))
        return cache

    def init_decode_state(self, params, batch: int, max_seq: int, *,
                          prompt=None, img_embeds=None, img_mask=None,
                          dtype=None, per_slot: bool = False) -> Dict[str, Any]:
        """``per_slot=True`` keeps one position per batch row (continuous
        batching): decode advances each slot independently and prefills can
        land rows at different depths via :meth:`insert_slot`."""
        pos0 = jnp.zeros((batch,), jnp.int32) if per_slot \
            else jnp.zeros((), jnp.int32)
        state: Dict[str, Any] = {"cache": self.init_cache(batch, max_seq, dtype),
                                 "pos": pos0}
        if self.cfg.is_moe:
            # router-load EWMA, uniform prior; decode_step folds each step's
            # observed routed-token fractions in (EXPERT_LOAD_EWMA decay)
            E = self.cfg.n_experts
            state["expert_load"] = jnp.full(
                (self.cfg.n_layers, E), 1.0 / E, jnp.float32)
        if self.is_vlm:
            state["img_kv"] = self._project_img_kv(params, img_embeds)
            state["img_mask"] = img_mask
        return state

    def prefill(self, params, state, tokens):
        """Run the prompt through the model, filling caches. Returns
        (last-token logits, state)."""
        cfg, part = self.cfg, self.part
        B, S = tokens.shape
        x = L.embed(cfg, params, tokens, part)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache, _, _ = self._run_layers(
            params, x, positions, state["cache"], jnp.zeros((), jnp.int32),
            img_kv=state.get("img_kv"), img_mask=state.get("img_mask"))
        x = L.apply_norm(cfg, params, "ln_f", x)
        logits = L.unembed(cfg, params, x[:, -1:, :], part)
        return logits[:, 0], dict(state, cache=new_cache,
                                  pos=jnp.asarray(S, jnp.int32))

    def decode_step(self, params, state, tokens):
        """One autoregressive step. tokens: (B,) int32. Returns (logits (B,V),
        new state).

        ``state["pos"]`` is either the shared scalar position (lock-step
        batch) or a (B,) vector (per-slot continuous batching): each row
        embeds/attends/writes at its own depth, so slots prefilled at
        different times decode together.
        """
        cfg, part = self.cfg, self.part
        B = tokens.shape[0]
        pos = state["pos"]
        per_slot = getattr(pos, "ndim", 0) == 1
        x = L.embed(cfg, params, tokens[:, None], part)
        if per_slot:
            positions = pos[:, None].astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        page_map = state.get("page_map")
        x, new_cache, _, freqs = self._run_layers(
            params, x, positions, state["cache"], pos,
            img_kv=state.get("img_kv"), img_mask=state.get("img_mask"),
            head_rows=state.get("head_rows"), head_inv=state.get("head_inv"),
            page_map=page_map)
        x = L.apply_norm(cfg, params, "ln_f", x)
        logits = L.unembed(cfg, params, x, part)
        if per_slot:
            # clamp retired slots at the cache edge (their writes drop);
            # the paged extent is the page table's logical span, not a
            # dense cache axis
            if page_map is not None:
                T = page_map.shape[1] * state["cache"]["k"].shape[2]
            else:
                T = state["cache"]["k"].shape[-3]
            new_pos = jnp.minimum(pos + 1, jnp.int32(T))
        else:
            new_pos = pos + 1
        new_state = dict(state, cache=new_cache, pos=new_pos)
        if freqs is not None and "expert_load" in state:
            d = jnp.float32(EXPERT_LOAD_EWMA)
            new_state["expert_load"] = (d * state["expert_load"]
                                        + (1.0 - d) * freqs)
        return logits[:, 0], new_state

    # ----------------------------------------------- continuous batching
    def prefill_bucketed(self, params, state, tokens, length):
        """Prefill right-padded prompts: ``tokens`` (B, Lb) padded to a
        bucket length, ``length`` (B,) true prompt lengths.  Returns the
        logits of each row's LAST REAL token and a per-slot state with
        ``pos == length``.  Padding rows write garbage K/V at indices
        >= length, but the causal mask hides index q until decode step q
        overwrites it first, so the garbage is never attended.  Compiles
        once per bucket length Lb, not per prompt length."""
        cfg, part = self.cfg, self.part
        B, S = tokens.shape
        x = L.embed(cfg, params, tokens, part)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache, _, _ = self._run_layers(
            params, x, positions, state["cache"], jnp.zeros((), jnp.int32),
            img_kv=state.get("img_kv"), img_mask=state.get("img_mask"))
        x = L.apply_norm(cfg, params, "ln_f", x)
        last = jnp.take_along_axis(
            x, jnp.maximum(length - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)                                      # (B, 1, D)
        logits = L.unembed(cfg, params, last, part)
        return logits[:, 0], dict(state, cache=new_cache,
                                  pos=jnp.asarray(length, jnp.int32))

    def insert_slot(self, state, sub, slot):
        """Copy a batch-1 prefilled ``sub`` state (cache length Lb <= T)
        into batch row ``slot`` of a persistent per-slot decode state:
        the slot-manager write of continuous batching.  ``slot`` may be a
        traced scalar — one compile serves every slot.

        K/V buffers carry their batch axis at ``ndim - 4`` (dense
        (L,B,T,KvE,dh) -> axis 1, VLM self caches (G,4,B,T,KvE,dh) -> axis
        2, VLM ``img_kv`` (G,B,I,KvE,dh) -> axis 1), so one splice rule
        covers every cache layout; VLM states additionally splice the
        request's static image K/V and mask rows."""
        slot = jnp.asarray(slot, jnp.int32)

        def splice_kv(dst, src, batch_axis):
            start = tuple(slot if a == batch_axis else jnp.int32(0)
                          for a in range(dst.ndim))
            return jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), start)

        cache, sub_cache = state["cache"], sub["cache"]
        upd = {name: splice_kv(cache[name], sub_cache[name],
                               cache[name].ndim - 4)
               for name in ("k", "v")}
        # int8 KV caches carry per-(token, head) scales whose batch axis
        # sits one dim closer to the front ((L, B, T, KvE) -> ndim - 3);
        # splicing values without their scales would dequantize garbage
        for name in ("k_sc", "v_sc"):
            if name in cache:
                upd[name] = splice_kv(cache[name], sub_cache[name],
                                      cache[name].ndim - 3)
        pos = jax.lax.dynamic_update_slice(
            state["pos"], jnp.asarray(sub["pos"], jnp.int32), (slot,))
        out = dict(state, cache=dict(cache, **upd), pos=pos)
        if "img_kv" in state and "img_kv" in sub:
            img = state["img_kv"]
            out["img_kv"] = dict(img, **{
                name: splice_kv(img[name], sub["img_kv"][name],
                                img[name].ndim - 4)
                for name in ("k", "v")})
        if state.get("img_mask") is not None and \
                sub.get("img_mask") is not None:
            out["img_mask"] = jax.lax.dynamic_update_slice(
                state["img_mask"],
                jnp.asarray(sub["img_mask"], state["img_mask"].dtype),
                (slot, jnp.int32(0)))
        return out

    # ------------------------------------------------------- paged caching
    def init_paged_cache(self, n_pages: int, page_size: int,
                         dtype=None) -> dict:
        """Pooled page store: stacked (L, n_pages, P, KvE, dh) — the
        batch × seq extent of the dense cache is replaced by a flat page
        axis shared by every slot, so resident bytes follow ALLOCATED
        pages, not ``n_slots * max_seq`` worst case.  int8-KV configs
        page their per-(token, head) scales alongside the values."""
        cfg = self.cfg
        if self.window:
            raise NotImplementedError(
                "paged caches are linear; sliding-window archs keep the "
                "ring cache")
        if self.is_vlm:
            raise NotImplementedError(
                "paged caches do not yet carry the VLM image K/V")
        dtype = dtype or jnp.dtype(cfg.dtype)
        lead = (cfg.n_layers,)
        shape = lead + (n_pages, page_size, self.hd.KvE, self.hd.dh)
        if cfg.kv_quant:
            return {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_sc": jnp.zeros(
                        lead + (n_pages, page_size, self.hd.KvE),
                        jnp.float32),
                    "v_sc": jnp.zeros(
                        lead + (n_pages, page_size, self.hd.KvE),
                        jnp.float32)}
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

    def init_paged_state(self, params, batch: int, n_pages: int,
                         page_size: int, pages_per_slot: int,
                         dtype=None) -> Dict[str, Any]:
        """Per-slot paged decode state: the page store, per-row positions,
        and the (batch, pages_per_slot) page table — all ``-1``
        (unmapped) until the engine mounts an allocation."""
        return {"cache": self.init_paged_cache(n_pages, page_size, dtype),
                "pos": jnp.zeros((batch,), jnp.int32),
                "page_map": jnp.full((batch, pages_per_slot), -1,
                                     jnp.int32)}

    def prefill_paged(self, params, state, tokens, row, start, length):
        """ONE fixed-shape chunk of a paged prefill: ``tokens`` (1, C)
        holds chunk tokens right-padded to the chunk size, ``row`` the
        slot row, ``start`` the chunk's absolute start position and
        ``length`` its valid token count — ALL traced scalars, so every
        chunk of every prompt in every slot runs the same single
        lowering (no bucket ladder).  K/V land in the slot's mapped pages
        (invalid tail writes drop); returns the logits of the chunk's
        last VALID token (meaningful on the final chunk) and the state
        with ``pos[row] = start + length``."""
        cfg, part = self.cfg, self.part
        B, C = tokens.shape
        row = jnp.asarray(row, jnp.int32)
        start = jnp.asarray(start, jnp.int32)
        length = jnp.asarray(length, jnp.int32)
        x = L.embed(cfg, params, tokens, part)
        positions = (start + jnp.arange(C, dtype=jnp.int32))[None, :]
        valid = (jnp.arange(C, dtype=jnp.int32) < length)[None, :]
        page_row = jax.lax.dynamic_slice_in_dim(
            state["page_map"], row, 1, axis=0)            # (1, np)
        x, new_cache, _, _ = self._run_layers(
            params, x, positions, state["cache"], None,
            page_map=page_row, write_valid=valid)
        x = L.apply_norm(cfg, params, "ln_f", x)
        last = jnp.take_along_axis(
            x, jnp.maximum(length - 1, 0)[None, None, None], axis=1)
        logits = L.unembed(cfg, params, last, part)
        pos = jax.lax.dynamic_update_slice(
            state["pos"], (start + length)[None], (row,))
        return logits[:, 0], dict(state, cache=new_cache, pos=pos)

    def mount_slot_pages(self, state, row, pages, pos):
        """Write slot ``row``'s page-table row (+ position) into a paged
        decode state — the paged analog of :meth:`insert_slot`, used at
        admission, page-boundary extension, and retire (all ``-1`` +
        pos 0: the row's writes drop and its reads are masked).  ``row``
        stays a traced scalar so ONE lowering serves every slot."""
        row = jnp.asarray(row, jnp.int32)
        pm = jax.lax.dynamic_update_slice(
            state["page_map"], jnp.asarray(pages, jnp.int32)[None, :],
            (row, jnp.int32(0)))
        ps = jax.lax.dynamic_update_slice(
            state["pos"], jnp.asarray(pos, jnp.int32)[None], (row,))
        return dict(state, page_map=pm, pos=ps)
