"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + one train step on CPU, asserting output shapes and
no NaNs; plus prefill+decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_archs
from repro.models.api import build_model
from repro.optim.adamw import AdamW
from tests.conftest import reduced_config

ALL = list(ASSIGNED_ARCHS) + ["paper-gpt"]


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            key, (B, 7, cfg.d_model))
        batch["img_mask"] = jnp.ones((B, 7), bool)
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_no_nan(arch, rng_key):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(rng_key)
    batch = _batch(cfg, rng_key)
    logits, aux = model.forward(params, batch["tokens"],
                                **{k: v for k, v in batch.items()
                                   if k.startswith("img")})
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL)
def test_train_step(arch, rng_key):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(rng_key)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg, rng_key)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, loss

    p1, o1, l1 = step(params, opt_state, batch)
    p2, o2, l2 = step(p1, o1, batch)
    assert jnp.isfinite(l1) and jnp.isfinite(l2)
    assert float(l2) < float(l1) + 1.0  # moving, not exploding
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p1))
    assert delta > 0


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_matches_forward(arch, rng_key):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = model.init(rng_key)
    B, S, G = 2, 9, 4
    toks = jax.random.randint(rng_key, (B, S + G), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "vlm":
        extras = {"img_embeds": 0.02 * jax.random.normal(rng_key,
                                                         (B, 7, cfg.d_model)),
                  "img_mask": jnp.ones((B, 7), bool)}
    full, _ = model.forward(params, toks, **extras)
    state = model.init_decode_state(params, B, S + G, **extras)
    logits, state = model.prefill(params, state, toks[:, :S])
    errs = [float(jnp.abs(logits - full[:, S - 1]).max())]
    for g in range(G):
        logits, state = model.decode_step(params, state, toks[:, S + g])
        errs.append(float(jnp.abs(logits - full[:, S + g]).max()))
    assert max(errs) < 2e-4, (arch, errs)


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned dimensions."""
    expect = {
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for name, (L, D, H, K, F, V) in expect.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, D, H, K, F, V), name
    r = get_config("rwkv6-7b")
    assert (r.n_layers, r.d_model, r.d_ff, r.vocab_size) == \
        (32, 4096, 14336, 65536)
    assert r.n_kv_heads == 0  # attention-free
    mx = get_config("mixtral-8x7b")
    assert mx.n_experts == 8 and mx.experts_per_token == 2
    assert mx.sliding_window == 4096
    z = get_config("zamba2-2.7b")
    assert z.ssm_state == 64 and z.shared_attn_every > 0
    assert get_config("qwen1.5-32b").qkv_bias
    assert len(list_archs()) >= 11
