"""Shared test fixtures. NOTE: no XLA device-count override here — smoke
tests and benches must see 1 CPU device (the 512-device override belongs
exclusively to launch/dryrun.py)."""
import jax
import pytest

from repro.configs import get_config


def reduced_config(name: str, **over):
    """Family-preserving reduced config for CPU smoke tests."""
    cfg = get_config(name)
    base = dict(d_model=64, d_ff=128, vocab_size=97,
                dtype="float32", param_dtype="float32")
    if cfg.n_heads:
        base.update(n_heads=4, d_head=16,
                    n_kv_heads=min(4, cfg.n_kv_heads or 4))
    if cfg.family == "vlm":
        base.update(n_layers=5)
    elif cfg.family == "hybrid":
        base.update(n_layers=4, shared_attn_every=2)
    elif cfg.family == "ssm":
        base.update(n_layers=2, n_heads=4, d_head=16)
    else:
        base.update(n_layers=2)
    if cfg.is_moe:
        base.update(n_experts=4, sliding_window=8)
    base.update(over)
    return cfg.with_overrides(**base)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
