"""Cross-device decode pipelining + the migrations the engine used to
skip: K=1 bit-for-bit equivalence, the D_pipe <= D_T invariant
(hypothesis), GQA group-granular migration through the engine (logits and
streams invariant), VLM slot wiring, and controller-interval scaling under
in-flight depth K."""
import numpy as np
import pytest

from repro.core import (ALL_POLICIES, CostModel, DeviceNetwork,
                        inference_delay, make_blocks, pipeline_bottleneck,
                        pipelined_inference_delay, pipelined_total_delay,
                        simulate, stage_partition, total_delay)
from repro.core.network import GBPS
from repro.core.placement_bridge import (apply_layer_head_perms,
                                         kv_group_perms, placement_to_perms,
                                         stage_slot_partition)
from repro.core.solver import exact_myopic


# ------------------------------------------------- K=1 bit-for-bit
@pytest.mark.parametrize("compute_mode", ["paper", "incremental"])
@pytest.mark.parametrize("layer_mode,n_layers", [("columns", 1), ("graph", 1),
                                                 ("graph", 4)])
def test_k1_equals_inference_delay_bit_for_bit(compute_mode, layer_mode,
                                               n_layers):
    """Acceptance: pipelined_inference_delay(..., k=1) == inference_delay
    exactly, on the same fixtures test_layered exercises."""
    blocks = make_blocks(4, n_layers if layer_mode == "graph" else 1)
    cost = CostModel(d_model=2048, n_heads=4, n_layers=n_layers,
                     compute_mode=compute_mode, layer_mode=layer_mode)
    net = DeviceNetwork.sample(4, seed=3)
    rng = np.random.default_rng(0)
    for tau in (1, 7, 50):
        p = rng.integers(0, 4, len(blocks))
        q = rng.integers(0, 4, len(blocks))
        assert pipelined_inference_delay(p, blocks, cost, net, tau, k=1) == \
            inference_delay(p, blocks, cost, net, tau)
        assert pipelined_total_delay(q, p, blocks, cost, net, tau, k=1) == \
            total_delay(q, p, blocks, cost, net, tau)


def test_pipelined_rejects_k_below_one():
    blocks = make_blocks(2)
    cost = CostModel(d_model=256, n_heads=2)
    net = DeviceNetwork.sample(2, seed=0)
    with pytest.raises(ValueError, match="k must be >= 1"):
        pipelined_inference_delay(np.zeros(4, int), blocks, cost, net, 1,
                                  k=0)


# --------------------------------------------- D_pipe <= D_T invariant
def test_dpipe_bounded_by_dt_hypothesis():
    """On random multi-layer graphs and placements, K in flight never
    exceeds the sequential per-token delay, and D_pipe is non-increasing
    in K (more overlap cannot slow the stream)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 5),
           st.integers(1, 6), st.integers(2, 6), st.integers(1, 40))
    def check(seed, n_layers, n_heads, n_dev, tau):
        rng = np.random.default_rng(seed)
        blocks = make_blocks(n_heads, n_layers)
        cost = CostModel(d_model=256, n_heads=n_heads, n_layers=n_layers,
                         layer_mode="graph",
                         compute_mode=("paper", "incremental")[seed % 2])
        net = DeviceNetwork.sample(n_dev, seed=seed % 1000,
                                   bw_range=(0.01 * GBPS, 5 * GBPS))
        place = rng.integers(0, n_dev, len(blocks))
        d_t = inference_delay(place, blocks, cost, net, tau)
        prev = d_t
        for k in (1, 2, 3, 8, 64):
            d_k = pipelined_inference_delay(place, blocks, cost, net, tau,
                                            k=k)
            assert d_k <= d_t * (1 + 1e-12), (k, d_k, d_t)
            assert d_k <= prev * (1 + 1e-12)
            prev = d_k

    check()


def test_single_device_placement_has_no_overlap():
    """Everything on the controller device: no links exist at all, the
    bottleneck IS the critical path, and pipelining gains nothing
    (D_pipe(k) == D_T for every k)."""
    blocks = make_blocks(4, 3)
    cost = CostModel(d_model=512, n_heads=4, n_layers=3, layer_mode="graph")
    net = DeviceNetwork.sample(3, seed=1)
    place = np.full(len(blocks), net.controller, dtype=int)
    d_t = inference_delay(place, blocks, cost, net, 4)
    for k in (2, 16):
        assert np.isclose(pipelined_inference_delay(place, blocks, cost,
                                                    net, 4, k=k), d_t)
    assert np.isclose(pipeline_bottleneck(place, blocks, cost, net, 4),
                      d_t)  # compute-only critical path == device busy time


def test_stage_partition_views():
    """Layer-disjoint placements split into stages; sharing a device
    merges the run."""
    blocks = make_blocks(2, 4)
    place = np.empty(len(blocks), dtype=int)
    for l, dev in enumerate((0, 0, 1, 2)):     # layers 0-1 share device 0
        place[l * 4:(l + 1) * 4] = dev
    stages = stage_partition(place, blocks)
    assert [sorted(s) for s, _ in stages] == [[0], [1], [2]]
    assert [ls for _, ls in stages] == [(0, 1), (2,), (3,)]
    slot_stages = stage_slot_partition(place, blocks, n_slots=2)
    # device 2 aliases slot 0 -> layer 3 folds into... slot sets only
    assert all(isinstance(s, frozenset) for s, _ in slot_stages)


# --------------------------------- pipeline-aware policy and solvers
def test_pipeline_aware_solver_and_policy_prefer_spread():
    """With k>1 the exact solver's objective is D_pipe + D_mig; its
    optimum is never worse-than-sequential, and the pipelined optimum
    delay is <= the sequential optimum's pipelined price."""
    blocks = make_blocks(2, 2)
    cost = CostModel(d_model=512, n_heads=2, n_layers=2, layer_mode="graph",
                     compute_mode="incremental")
    net = DeviceNetwork.sample(3, seed=5, bw_range=(0.5 * GBPS, 5 * GBPS))
    p_seq, v_seq = exact_myopic(blocks, cost, net, 3, None)
    p_pipe, v_pipe = exact_myopic(blocks, cost, net, 3, None, pipeline_k=4)
    assert p_pipe is not None
    assert v_pipe <= pipelined_total_delay(None, p_seq, blocks, cost, net,
                                           3, k=4) + 1e-12
    assert v_pipe <= v_seq + 1e-12   # D_pipe <= D_T pointwise

    pol = ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.5,
                                         pipeline_k=4)
    res = simulate(pol, blocks, cost, net, 6, seed=0, fluctuate=False,
                   pipeline_k=4)
    pol0 = ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.5)
    res0 = simulate(pol0, blocks, cost, net, 6, seed=0, fluctuate=False)
    assert res.total_latency <= res0.total_latency + 1e-12


# ------------------------------------------------ group-consistent perms
def test_placement_to_perms_group_consistent_and_moves():
    blocks = make_blocks(4, 1)
    # g0 on slot 1, g1 on slot 3 -> relocation of g0 changes the perm
    p1 = np.array([1, 1, 3, 3, 0, 0])
    p2 = np.array([2, 2, 3, 3, 0, 0])
    perm1 = placement_to_perms(p1, blocks, 4, 1, group_size=2)
    perm2 = placement_to_perms(p2, blocks, 4, 1, group_size=2)
    assert not np.array_equal(perm1, perm2)
    for perm in (perm1, perm2):
        kv = kv_group_perms(perm, 2)          # validates + induces
        assert sorted(kv[0].tolist()) == [0, 1]
    # non-group-consistent permutations are refused, not silently applied
    with pytest.raises(ValueError, match="group-consistent"):
        kv_group_perms(np.array([[1, 2, 3, 0]]), 2)
    import jax.numpy as jnp
    cache = jnp.zeros((1, 1, 4, 2, 4))
    with pytest.raises(ValueError, match="group-consistent"):
        apply_layer_head_perms(cache, cache, np.array([[1, 2, 3, 0]]),
                               layer_axis=0, head_axis=-2, group_size=2)


# ---------------------------------------- GQA migration via the engine
def test_gqa_group_migration_logits_invariant():
    """Acceptance: a GQA config physically migrates KV groups — per-layer
    group-consistent permutations applied to weights AND grouped cache
    leave the next decode step's logits invariant."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from tests.conftest import reduced_config
    from repro.core.placement_bridge import permute_model_heads_layers
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b", n_kv_heads=2)     # GQA: G = 2
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    assert eng.model.hd.groups == 2
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(rng.integers(0, 97, size=n), max_new_tokens=4)
    eng._admit()
    for _ in range(2):                                  # populate caches
        eng.step()
    ref, _ = eng.model.decode_step(eng.params, eng.state,
                                   jnp.asarray(eng._next))
    # per-layer, genuinely different group swaps (layer 0 swaps, 1 doesn't)
    perms = np.array([[2, 3, 0, 1], [0, 1, 2, 3]])
    params2 = permute_model_heads_layers(eng.params, perms, group_size=2)
    k2, v2 = apply_layer_head_perms(eng.state["cache"]["k"],
                                    eng.state["cache"]["v"], perms,
                                    layer_axis=0, head_axis=-2,
                                    group_size=2)
    assert k2.shape == eng.state["cache"]["k"].shape    # KvE axis stays 2
    assert not np.array_equal(np.asarray(k2),
                              np.asarray(eng.state["cache"]["k"]))
    state2 = dict(eng.state, cache=dict(eng.state["cache"], k=k2, v=v2))
    out, _ = eng.model.decode_step(params2, state2, jnp.asarray(eng._next))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


def test_gqa_migration_roundtrip_through_engine():
    """End-to-end: the controller migrates a GQA cache mid-serve (no
    silent skip — the log reports applied migrations) and the generated
    streams equal a migration-free run."""
    pytest.importorskip("jax")
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b", n_kv_heads=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def run(lam, straggle_at):
        # 2 devices: each mesh slot holds exactly one KV group
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                            net=DeviceNetwork.sample(2, seed=1))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                dev = int(eng.controller.head_counts().argmax())
                eng.net.inject_straggler(dev, slowdown=500.0)
            if not eng.step():
                break
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    with_mig, eng = run(3, straggle_at=4)
    without, _ = run(10 ** 9, None)
    assert with_mig == without and len(with_mig) == 5
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "GQA migration silently skipped"
    assert all(e["reason"] is None for e in applied)


# ---------------------------------- int8 (kv_quant) continuous serving
def test_kv_quant_continuous_migration_roundtrip():
    """supports_continuous no longer refuses kv_quant: the continuous
    engine runs the int8 KV path (per-slot quantized writes, insert_slot
    splices values AND scales), a controller migration physically applies
    (values + per-(token, head) scale rows permuted together), and the
    streams equal a migration-free run — on the jnp int8 path and through
    the fused-int8 resident kernel."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine, supports_continuous

    cfg = reduced_config("llama3-8b", kv_quant=True)
    assert supports_continuous(cfg) is None
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def run(lam, straggle_at, use_kernel=False):
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                            net=DeviceNetwork.sample(2, seed=1),
                            use_kernel=use_kernel)
        assert eng.state["cache"]["k"].dtype == jnp.int8
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                dev = int(eng.controller.head_counts().argmax())
                eng.net.inject_straggler(dev, slowdown=500.0)
            if not eng.step():
                break
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    with_mig, eng = run(3, straggle_at=4)
    without, _ = run(10 ** 9, None)
    assert with_mig == without and len(with_mig) == 5
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "int8 migration skipped instead of applied"
    assert all(e["reason"] is None for e in applied)
    # fused-int8 resident kernel: same streams, before AND after migration
    kern_mig, keng = run(3, straggle_at=4, use_kernel=True)
    assert kern_mig == without
    assert [e for e in keng.migration_log
            if e["applied"] and e["n_migrations"]]


# ----------------------------------- rep>1 replica-aware KV migration
def test_rep_gt1_migration_applies_with_logits_invariance():
    """tp > n_kv_heads replicates KV heads (HeadDims.rep > 1); migration
    used to return (state, False, "rep>1 ..."). Supergroup-consistent
    permutations now move q-head rows with their replicated KV rows:
    per-layer perms applied to weights AND cache leave the next decode
    step's logits invariant."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from tests.conftest import reduced_config
    from repro.core.placement_bridge import (expand_kv_perms,
                                             permute_model_heads_layers)
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b", n_heads=8, d_head=8, n_kv_heads=2)
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0,
                        tp=4, net=DeviceNetwork.sample(4, seed=1))
    hd = eng.model.hd
    assert (hd.rep, hd.Kp, hd.KvE) == (2, 2, 4)
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(rng.integers(0, 97, size=n), max_new_tokens=4)
    eng._admit()
    for _ in range(2):
        eng.step()
    ref, _ = eng.model.decode_step(eng.params, eng.state,
                                   jnp.asarray(eng._next))
    # layer 0 swaps the two supergroups (Hp//Kp = 4 heads each), layer 1
    # stays — a genuinely per-layer replica-aware move
    perms = np.array([[4, 5, 6, 7, 0, 1, 2, 3], np.arange(8)])
    params2 = permute_model_heads_layers(eng.params, perms, group_size=4)
    np.testing.assert_array_equal(
        expand_kv_perms(np.array([[1, 0]]), 2), [[2, 3, 0, 1]])
    k2, v2 = apply_layer_head_perms(eng.state["cache"]["k"],
                                    eng.state["cache"]["v"], perms,
                                    layer_axis=0, head_axis=-2,
                                    group_size=4, rep=2)
    assert not np.array_equal(np.asarray(k2),
                              np.asarray(eng.state["cache"]["k"]))
    state2 = dict(eng.state, cache=dict(eng.state["cache"], k=k2, v=v2))
    out, _ = eng.model.decode_step(params2, state2, jnp.asarray(eng._next))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


def test_rep_gt1_migration_roundtrip_through_engine():
    """End-to-end: a rep>1 engine's controller migration applies (no
    'rep>1 KV replication is not migratable' skip) and streams equal the
    migration-free run."""
    pytest.importorskip("jax")
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b", n_heads=8, d_head=8, n_kv_heads=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def run(lam, straggle_at):
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                            tp=4, net=DeviceNetwork.sample(4, seed=1))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                dev = int(eng.controller.head_counts().argmax())
                eng.net.inject_straggler(dev, slowdown=500.0)
            if not eng.step():
                break
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    with_mig, eng = run(3, straggle_at=4)
    without, _ = run(10 ** 9, None)
    assert with_mig == without and len(with_mig) == 5
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "rep>1 migration still reported-but-skipped"
    assert all(e["reason"] is None for e in applied)
    assert not any("rep>1" in (e["reason"] or "")
                   for e in eng.migration_log)


# ----------------------------------------------------- VLM slot wiring
def test_vlm_requests_are_slot_wired():
    """VLM decode states (img_kv, grouped caches) splice per slot: each
    request's stream matches the single-request reference, and the image
    content genuinely matters (nonzero cross-attn gates)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine, make_engine

    cfg = reduced_config("llama-3.2-vision-11b")
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0,
                        img_tokens=8)
    assert isinstance(make_engine(cfg, n_slots=2, max_seq=32, seed=0),
                      ServingEngine)
    gates = eng.params["cross_layers"]["attn"]["gate"]
    eng.params["cross_layers"]["attn"]["gate"] = jnp.ones_like(gates) * 0.7
    eng.params["cross_layers"]["gate_ffn"] = \
        jnp.ones_like(eng.params["cross_layers"]["gate_ffn"]) * 0.5

    prompts = [rng.integers(0, 97, size=n).astype(np.int32)
               for n in (4, 7, 9)]
    imgs = [rng.standard_normal((5, cfg.d_model)).astype(np.float32),
            None,                                   # imageless request
            rng.standard_normal((8, cfg.d_model)).astype(np.float32)]
    for p, im in zip(prompts, imgs):
        eng.submit(p, max_new_tokens=5, img_embeds=im)
    done = eng.run()
    assert len(done) == 3

    def reference(prompt, img):
        pad = np.zeros((eng.img_tokens, cfg.d_model), np.float32)
        mask = np.zeros((eng.img_tokens,), bool)
        if img is not None:
            pad[:img.shape[0]] = img
            mask[:img.shape[0]] = True
        state = eng.model.init_decode_state(
            eng.params, 1, 48, img_embeds=jnp.asarray(pad[None]),
            img_mask=jnp.asarray(mask[None]))
        logits, state = eng.model.prefill(
            eng.params, state, jnp.asarray(prompt[None], jnp.int32))
        toks = [int(jnp.argmax(logits[0]))]
        step = jax.jit(eng.model.decode_step, donate_argnums=(1,))
        for _ in range(4):
            logits, state = step(eng.params, state,
                                 jnp.asarray([toks[-1]], jnp.int32))
            # rpr: ignore[RPR004] -- reference decoder reads its greedy
            # stream back per step to feed the next token
            toks.append(int(jnp.argmax(logits[0])))
        return toks

    for r in sorted(done, key=lambda r: r.rid):
        assert r.out_tokens == reference(prompts[r.rid], imgs[r.rid]), \
            f"rid {r.rid}"
    # the image is load-bearing, not decorative
    assert reference(prompts[0], imgs[0]) != reference(prompts[0], None)


def test_unsupported_archs_raise_typed_error_at_construction():
    pytest.importorskip("jax")
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine, UnsupportedArchError

    for arch in ("rwkv6-7b", "zamba2-2.7b", "mixtral-8x7b"):
        with pytest.raises(UnsupportedArchError):
            ServingEngine(reduced_config(arch), n_slots=2, max_seq=32,
                          seed=0)
    # GQA geometry that the group blocks cannot tile is rejected at
    # construction too, never mid-serve (3 devices x 1 head/slot, G=2)
    with pytest.raises(UnsupportedArchError, match="group size"):
        ServingEngine(reduced_config("llama3-8b", n_kv_heads=2),
                      n_slots=2, max_seq=32, seed=0,
                      net=DeviceNetwork.sample(3, seed=1))
    # non-VLM engines reject image payloads at intake
    eng = ServingEngine(reduced_config("llama3-8b"), n_slots=2, max_seq=32,
                        seed=0)
    with pytest.raises(ValueError, match="not a VLM"):
        eng.submit(np.zeros(4, np.int32), img_embeds=np.zeros((4, 64)))


# --------------------------------- controller interval under pipelining
def test_interval_cadence_scales_with_pipeline_depth():
    """A slot emits one token every K steps, so λ tokens per slot = λ·K
    scheduler steps: intervals fire at multiples of lam*K and the streams
    stay identical to sequential decode."""
    pytest.importorskip("jax")
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (4, 9, 6, 11)]

    def run(k, lam):
        eng = ServingEngine(cfg, n_slots=4, max_seq=48, lam=lam, seed=0,
                            pipeline_k=k)
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        eng.run()
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    seq, e1 = run(1, lam=4)
    pipe, e2 = run(2, lam=4)
    assert seq == pipe and len(pipe) == 4
    assert e1.migration_log and e2.migration_log
    assert all(e["step"] % 4 == 0 for e in e1.migration_log)
    assert all(e["step"] % 8 == 0 for e in e2.migration_log)
    # same token-denominated cadence: K=2 fires half as often per step
    # but identically per generated token
    assert len(e2.migration_log) <= len(e1.migration_log)

    with pytest.raises(ValueError, match="divisible"):
        ServingEngine(cfg, n_slots=3, max_seq=48, seed=0, pipeline_k=2)
    with pytest.raises(ValueError, match="greedy"):
        ServingEngine(cfg, n_slots=4, max_seq=48, seed=0, pipeline_k=2,
                      greedy=False)
