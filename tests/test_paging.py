"""Paged KV cache: allocator properties (no leaks, no aliasing, typed
exhaustion), paged-vs-dense stream bit-identity on dense/GQA/int8-KV
configs before and after an applied migration, chunked-prefill lowering
bound, page-granular migration bytes, ring-kernel stream parity, and the
chain re-seed skip."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (BottleneckAwarePolicy, CostModel, DeviceNetwork,
                        make_blocks)
from repro.core.network import GBPS
from repro.serving.engine import (ServingEngine, UnsupportedArchError,
                                  WaveServingEngine)
from repro.serving.paging import PagedKVAllocator, PageExhaustedError
from tests.conftest import reduced_config


# ------------------------------------------------- allocator properties
def test_allocator_no_leaks_across_admit_retire_cycles():
    """Random admit/extend/release churn: the invariants (free + live ==
    total, no aliasing, no page both free and live) hold after EVERY op,
    and a full drain returns the pool to its initial state."""
    rng = np.random.default_rng(0)
    alloc = PagedKVAllocator(n_pages=16, page_size=4, n_rows=4,
                             max_pages_per_slot=8)
    live_rows = set()
    for _ in range(300):
        op = rng.integers(0, 3)
        if op == 0 and len(live_rows) < 4:
            row = next(r for r in range(4) if r not in live_rows)
            n = int(rng.integers(1, 9))
            horizon = n + int(rng.integers(0, 8))
            if alloc.can_admit(n, horizon):
                pages = alloc.admit(row, n, horizon)
                assert len(pages) == -(-n // 4)
                live_rows.add(row)
        elif op == 1 and live_rows:
            row = rng.choice(sorted(live_rows))
            try:
                alloc.extend(row, alloc.pages_for(row) * 4
                             + int(rng.integers(1, 5)))
            except PageExhaustedError:
                pass                      # over-reservation growth may fail
        elif op == 2 and live_rows:
            row = rng.choice(sorted(live_rows))
            alloc.release(row)
            live_rows.discard(row)
        alloc.check_invariants()
    for row in sorted(live_rows):
        alloc.release(row)
    alloc.check_invariants()
    assert alloc.live_pages == 0 and alloc.reserved_pages == 0
    assert alloc.free_pages == 16


def test_allocator_no_page_aliasing_between_slots():
    alloc = PagedKVAllocator(n_pages=8, page_size=2, n_rows=4,
                             max_pages_per_slot=2)
    owned = [alloc.admit(r, n_tokens=4, horizon=4) for r in range(4)]
    flat = [p for pages in owned for p in pages]
    assert len(flat) == len(set(flat)) == 8
    # page-map rows mirror exactly the owned ids, -1 padded
    for r in range(4):
        np.testing.assert_array_equal(alloc.page_map_row(r), owned[r])


def test_allocator_exhaustion_raises_typed_error():
    alloc = PagedKVAllocator(n_pages=4, page_size=4, n_rows=4,
                             max_pages_per_slot=4)
    # over-size: can never fit regardless of pool state
    assert not alloc.can_admit(100, 100)
    with pytest.raises(PageExhaustedError, match="max_pages_per_slot"):
        alloc.admit(0, n_tokens=100, horizon=100)
    # pool pressure: reservations block a second admission
    alloc.admit(0, n_tokens=4, horizon=12)     # 1 live + 2 reserved
    assert not alloc.can_admit(8, 8)
    with pytest.raises(PageExhaustedError, match="exhausted"):
        alloc.admit(1, n_tokens=8, horizon=8)
    assert isinstance(PageExhaustedError("x"), RuntimeError)
    alloc.check_invariants()


def test_allocator_extension_never_fails_within_reservation():
    """The engine's invariant: admission reserves the decode horizon, so
    mid-stream extension up to it always succeeds — even when the rest of
    the pool has been handed to other rows."""
    alloc = PagedKVAllocator(n_pages=8, page_size=2, n_rows=4,
                             max_pages_per_slot=4)
    alloc.admit(0, n_tokens=2, horizon=8)      # 1 live + 3 reserved
    alloc.admit(1, n_tokens=8, horizon=8)      # eats 4 of remaining
    assert alloc.free_pages - alloc.reserved_pages == 0
    for t in (4, 6, 8):                        # grows inside reservation
        alloc.extend(0, t)
        alloc.check_invariants()
    with pytest.raises(PageExhaustedError):
        alloc.extend(0, 10)                    # beyond reservation + free
    alloc.release(1)
    alloc.release(0)
    assert alloc.free_pages == 8


# --------------------------------------- paged vs dense stream identity
def _streams(cfg, prompts, *, paged, lam=10 ** 9, straggle_at=None,
             use_kernel=False, n_dev=2, max_new=8):
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                        net=DeviceNetwork.sample(n_dev, seed=1),
                        use_kernel=use_kernel, paged=paged, page_size=8)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=max_new + (i % 2))
    while True:
        if straggle_at is not None and eng.decode_steps == straggle_at:
            dev = int(eng.controller.head_counts().argmax())
            eng.net.inject_straggler(dev, slowdown=500.0)
        if not eng.step():
            break
    return {r.rid: r.out_tokens for r in eng.finished}, eng


@pytest.mark.parametrize("over", [{}, {"n_kv_heads": 2},
                                  {"kv_quant": True}],
                         ids=["dense", "gqa", "int8kv"])
def test_paged_streams_bit_identical_to_dense(over):
    """Acceptance: the paged engine streams exactly the dense engine's
    greedy tokens — page gather/scatter is a pure re-layout (same extents,
    same reduction order, masked garbage multiplied by exact 0.0)."""
    cfg = reduced_config("llama3-8b", **over)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 97, size=n).astype(np.int32)
               for n in (5, 11, 3, 17)]
    want, _ = _streams(cfg, prompts, paged=False)
    got, eng = _streams(cfg, prompts, paged=True)
    assert got == want and len(got) == 4
    # all pages returned to the pool after the last retire
    for a in eng.allocators:
        a.check_invariants()
        assert a.live_pages == 0


def test_paged_streams_survive_applied_migration():
    """A mid-stream head migration on the paged engine (kernel path, grid
    rebuilt from the plan) leaves the streams bit-identical to the dense
    engine under the SAME straggler schedule, and to a migration-free
    paged run."""
    cfg = reduced_config("llama3-8b", n_layers=3, n_kv_heads=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n).astype(np.int32)
               for n in (5, 11, 8, 14)]
    run = dict(lam=3, straggle_at=4, use_kernel=True, max_new=10)
    got, eng = _streams(cfg, prompts, paged=True, **run)
    want, _ = _streams(cfg, prompts, paged=False, **run)
    free, _ = _streams(cfg, prompts, paged=True, max_new=10)
    assert got == want == free and len(got) == 4
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "no migration was physically applied"
    for a in eng.allocators:
        a.check_invariants()
        assert a.live_pages == 0


# --------------------------------------------- chunked prefill lowering
def test_chunked_prefill_is_one_lowering():
    """Mixed prompt lengths splice through ONE fixed-shape prefill jit
    (row/start/length traced) — no bucketed recompile ladder."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 97, size=n).astype(np.int32)
               for n in (3, 9, 14, 21, 6)]
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=10 ** 9, seed=0,
                        paged=True, page_size=8)
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    done = eng.run()
    assert len(done) == 5
    assert eng._paged_prefill_jit._cache_size() == 1
    assert eng._mount_jit._cache_size() == 1
    # the dense engine's bucket ladder would have needed >= 3 lowerings
    assert len(eng.prefill_buckets_used) == 1


# --------------------------------------------- page-granular migration
def test_migration_bytes_priced_from_live_pages():
    """Pages are the migration unit: a head migration on the paged engine
    is priced on allocated pages only — far below the dense engine's
    worst-case ``n_slots x max_seq`` extent — and exactly matches the
    closed-form per-row byte count."""
    cfg = reduced_config("llama3-8b")
    kw = dict(n_slots=2, max_seq=64, lam=10 ** 9, seed=0)
    dense = ServingEngine(cfg, **kw)
    paged = ServingEngine(cfg, paged=True, page_size=8, **kw)
    prompt = np.arange(5, dtype=np.int32) % 97
    for eng in (dense, paged):
        eng.submit(prompt, max_new_tokens=4)
        eng._admit()
    # one slot holding a 5-token prompt: 1 live page = 8 tokens
    assert paged._live_cache_tokens() == 8
    assert dense._live_cache_tokens() == 2 * 64
    pairs = [(0, 0, 0, 1)]                 # one head, one layer
    hd = paged.model.hd
    itm = jnp.dtype(cfg.dtype).itemsize
    assert paged._migration_bytes(pairs) == hd.rep * 8 * 2 * hd.dh * itm
    assert dense._migration_bytes(pairs) == \
        paged._migration_bytes(pairs) * (2 * 64) // 8
    # and the interval log carries the live-page figure
    paged._log_interval({"migrations": pairs, "d_mig_est": 0.0}, False,
                        "test")
    assert paged.migration_log[-1]["mig_bytes"] == \
        paged._migration_bytes(pairs)


def test_paged_admission_head_of_line_blocks_until_pages_free():
    """A request whose horizon cannot be reserved waits in the queue (no
    mid-stream exhaustion by construction) and is admitted once a retire
    returns pages."""
    cfg = reduced_config("llama3-8b")
    # pool of 4 pages total; each request needs 2 (prompt 5 -> 1 page,
    # horizon 5+4+1=10 -> 2 pages)
    eng = ServingEngine(cfg, n_slots=2, max_seq=16, lam=10 ** 9, seed=0,
                        paged=True, page_size=8, kv_pages=4)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, 97, size=5), max_new_tokens=4)
    done = eng.run()
    assert len(done) == 3
    third = next(a for a in eng.admission_log if a["rid"] == 2)
    assert third["step"] > 0               # waited for a retire
    for a in eng.allocators:
        assert a.live_pages == 0


def test_paged_rejects_vlm():
    cfg = reduced_config("llama-3.2-vision-11b")
    with pytest.raises(UnsupportedArchError, match="paged"):
        ServingEngine(cfg, n_slots=2, max_seq=64, seed=0, paged=True,
                      page_size=8)


# ------------------------------------------------- ring-cache kernel
def test_ring_kernel_streams_match_jnp(monkeypatch):
    """Sliding-window (ring cache) decode through the resident kernel:
    greedy streams equal the jnp path, and the kernel branch actually
    dispatched (no silent fall-through)."""
    from repro.kernels import ops
    calls = {"n": 0}
    orig = ops.decode_attention_ring_bshd

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    cfg = reduced_config("mixtral-8x7b")
    assert cfg.sliding_window == 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=6).astype(np.int32)
               for _ in range(2)]
    outs = {}
    for uk in (False, True):
        if uk:
            monkeypatch.setattr(ops, "decode_attention_ring_bshd", spy)
        eng = WaveServingEngine(cfg, n_slots=2, max_seq=32, lam=10 ** 9,
                                seed=0, use_kernel=uk)
        for p in prompts:
            eng.submit(p, max_new_tokens=12)   # decode past the window
        done = eng.run()
        outs[uk] = {r.rid: r.out_tokens for r in done}
    assert outs[True] == outs[False] and len(outs[True]) == 2
    assert calls["n"] >= 1, "ring kernel never dispatched"


# --------------------------------------------------- chain re-seed skip
def test_chain_reseed_skipped_when_incumbent_unchanged():
    """The bottleneck search re-seeds from the stage-balanced chain only
    when the incumbent placement moved: after the chain loses once, the
    same ``prev`` skips the seed+refine pass entirely (counters expose
    the memo), and any adoption or incumbent change re-arms it."""
    blocks = make_blocks(4, 3)
    cost = CostModel(d_model=1024, n_heads=4, n_layers=3,
                     layer_mode="graph", compute_mode="incremental")
    net = DeviceNetwork.sample(4, seed=3,
                               bw_range=(0.05 * GBPS, 2 * GBPS))
    pol = BottleneckAwarePolicy(blocks, cost, deadline=0.5, pipeline_k=2)
    prev = pol.place(net, 1, None)
    before = (pol.chain_reseeds, pol.chain_reseed_skips)
    out1 = pol.place(net, 2, prev)
    if pol._chain_lost_to is None:
        pytest.skip("chain candidate adopted on this topology")
    assert pol.chain_reseeds == before[0] + 1
    # same incumbent again: the whole seed+refine race is skipped and the
    # result is identical (the race is deterministic in prev)
    out2 = pol.place(net, 2, prev)
    assert pol.chain_reseed_skips == before[1] + 1
    assert np.array_equal(out1, out2)
    # a different incumbent re-arms the re-seed
    moved = np.asarray(prev).copy()
    moved[0] = (moved[0] + 1) % net.n_devices
    pol.place(net, 2, moved)
    assert pol.chain_reseeds == before[0] + 2
