"""The paper's algorithm: Table-I cost model, scoring, Algorithm 1
invariants, exact-solver gap, baseline ordering, simulator claims."""
import numpy as np

from repro.core import (ALL_POLICIES, DeviceNetwork, ResourceAwarePolicy,
                        exact_myopic, inference_delay, memory_feasible,
                        memory_usage, migration_delay, simulate,
                        total_delay)
from repro.core.algorithm import ResourceAwareAssigner
from repro.core.blocks import CostModel, FFN, make_blocks
from repro.core.solver import exact_horizon

GB = 1024 ** 3


def small_setup(n_heads=4, n_dev=4, seed=1, **cost_kw):
    blocks = make_blocks(n_heads)
    cost = CostModel(d_model=2048, n_heads=n_heads, L0=64, **cost_kw)
    net = DeviceNetwork.sample(n_dev, seed=seed)
    return blocks, cost, net


# ---------------------------------------------------------------- Table I
def test_table1_formulas_as_printed():
    cost = CostModel(d_model=2048, n_heads=32, L0=64, bytes_per_param=2,
                     flops_per_mac=1)  # table counts MACs
    blocks = make_blocks(32)
    head, proj, ffn = blocks[0], blocks[-2], blocks[-1]
    tau = 10
    L, D, d, b = 74, 2048, 64, 2
    assert cost.memory(head, tau) == 3 * L * d * b + 3 * D * d * b + tau * D * b
    assert cost.memory(proj, tau) == L * D * b
    assert cost.memory(ffn, tau) == 4 * L * D * b
    assert cost.compute(head, tau) == 3 * L * D * d + L * L * d
    assert cost.compute(proj, tau) == L * D * D
    assert cost.compute(ffn, tau) == 8 * L * D * D


def test_costs_grow_with_tau():
    """Autoregressive growth: m_i and b_i strictly increase in τ (§III.C)."""
    blocks, cost, _ = small_setup()
    for bl in blocks:
        m = [cost.memory(bl, t) for t in (1, 10, 100)]
        c = [cost.compute(bl, t) for t in (1, 10, 100)]
        assert m[0] < m[1] < m[2]
        assert c[0] < c[1] < c[2]


def test_cache_modes():
    paper = CostModel(d_model=2048, n_heads=32, cache_mode="paper")
    precise = CostModel(d_model=2048, n_heads=32, cache_mode="precise")
    h = make_blocks(32)[0]
    # paper-as-printed counts τ·D·b per head; precise counts 2·τ·d·b
    delta_paper = paper.memory(h, 11) - paper.memory(h, 10)
    delta_precise = precise.memory(h, 11) - precise.memory(h, 10)
    # subtract the 3·L·d·b activation growth common to both
    act = 3 * 1 * 64 * 2
    assert delta_paper - act == 2048 * 2
    assert delta_precise - act == 2 * 64 * 2


# ---------------------------------------------------------------- delays
def test_migration_delay_eq2():
    blocks, cost, net = small_setup()
    prev = np.zeros(len(blocks), dtype=int)
    place = prev.copy()
    place[0] = 1  # one head migrates 0 -> 1
    d = migration_delay(prev, place, blocks, cost, net, tau=5)
    want = cost.memory(blocks[0], 4) / net.bandwidth[0, 1]
    assert abs(d - want) < 1e-12
    assert migration_delay(None, place, blocks, cost, net, 5) == 0.0


def test_inference_delay_parallel_heads_beat_colocated():
    """Spreading heads over idle devices must not be slower (paper's core
    premise: parallel execution of attention heads)."""
    blocks, cost, net = small_setup(n_heads=4, n_dev=4, seed=3)
    net.compute_avail[:] = net.compute_avail.mean()
    net.bandwidth[:] = 1e12     # comm negligible
    together = np.zeros(len(blocks), dtype=int)
    spread = np.array([0, 1, 2, 3, 0, 0])
    d_together = inference_delay(together, blocks, cost, net, 5)
    d_spread = inference_delay(spread, blocks, cost, net, 5)
    assert d_spread < d_together


def test_link_serialization():
    """Heads sharing one link serialize their transfers (§III.E)."""
    blocks, cost, net = small_setup(n_heads=4, n_dev=2, seed=0)
    net.bandwidth[:] = 1e6  # slow links -> comm dominates
    np.fill_diagonal(net.bandwidth, np.inf)
    all_on_1 = np.full(len(blocks), 1)
    all_on_1[-2:] = 0  # proj+ffn on 0 => 4 heads send over link (1,0)
    d = inference_delay(all_on_1, blocks, cost, net, 2)
    single = cost.head_to_proj_bytes(2) / net.bandwidth[1, 0]
    assert d >= 4 * single  # serialized, not parallel


# ------------------------------------------------------------ Algorithm 1
def test_algorithm1_respects_memory():
    blocks, cost, net = small_setup(n_heads=8, n_dev=4, seed=2,
                                    n_layers=32, compute_mode="incremental")
    net.mem_capacity[:] = 0.7 * memory_usage(
        np.zeros(len(blocks), int), blocks, cost, net, 50).max()
    assigner = ResourceAwareAssigner(blocks, cost, deadline=0.5)
    place, stats = assigner.assign(net, 50, None)
    assert place is not None
    assert memory_feasible(place, blocks, cost, net, 50)


def test_algorithm1_infeasible_when_impossible():
    blocks, cost, net = small_setup(n_heads=4, n_dev=3)
    net.mem_capacity[:] = 10.0  # bytes — nothing fits
    assigner = ResourceAwareAssigner(blocks, cost)
    place, stats = assigner.assign(net, 1, None)
    assert place is None and stats.infeasible


def test_algorithm1_iteration_bound():
    blocks, cost, net = small_setup(n_heads=6, n_dev=3)
    assigner = ResourceAwareAssigner(blocks, cost)
    place, stats = assigner.assign(net, 3, None)
    U = len(blocks) * net.n_devices
    assert stats.migrations <= U and stats.backtracks <= U


def test_hysteresis_prevents_thrash():
    """Identical consecutive resource states => no migrations."""
    blocks, cost, net = small_setup(n_heads=8, n_dev=5, seed=4,
                                    n_layers=32, compute_mode="incremental")
    pol = ResourceAwarePolicy(blocks, cost, deadline=0.2)
    p1 = pol.place(net, 1, None)
    p2 = pol.place(net, 2, p1)
    assert (p1 == p2).mean() > 0.9  # essentially no churn


def test_straggler_triggers_migration():
    """A persistent straggler hosting heavy blocks must shed them."""
    blocks, cost, net = small_setup(n_heads=8, n_dev=4, seed=5,
                                    n_layers=32, compute_mode="incremental")
    pol = ResourceAwarePolicy(blocks, cost, deadline=0.2)
    p1 = pol.place(net, 1, None)
    loaded = np.bincount(p1, minlength=net.n_devices).argmax()
    net.inject_straggler(int(loaded), slowdown=20.0)
    p2 = pol.place(net, 2, p1)
    assert (p2 == loaded).sum() < (p1 == loaded).sum()


# ------------------------------------------------------- solver + claims
def test_exact_solver_is_lower_bound():
    blocks, cost, net = small_setup(n_heads=4, n_dev=3, seed=7,
                                    n_layers=32, compute_mode="incremental")
    p_star, v_star = exact_myopic(blocks, cost, net, 1, None)
    assert p_star is not None
    for name, P in ALL_POLICIES.items():
        if name in ("edgeshard", "galaxy"):
            continue  # pipeline baselines use their own delay semantics
        pol = P(blocks, cost)
        p = pol.place(net, 1, None)
        assert total_delay(None, p, blocks, cost, net, 1) >= v_star - 1e-12


def test_paper_claim_small_scale_gap():
    """§V.C: resource-aware within 15-20% of the exact optimum (myopic
    chain over N=4 tokens), averaged over seeds/device counts."""
    ratios = []
    for nd, seed in [(3, 3), (4, 1), (5, 5), (4, 9)]:
        blocks, cost, net = small_setup(n_heads=4, n_dev=nd, seed=seed,
                                        n_layers=32,
                                        compute_mode="incremental")
        prev_e = prev_r = None
        tot_e = tot_r = 0.0
        pol = ResourceAwarePolicy(blocks, cost, deadline=0.2)
        for tau in range(1, 5):
            pe, ve = exact_myopic(blocks, cost, net, tau, prev_e)
            tot_e += ve
            pr = pol.place(net, tau, prev_r)
            tot_r += total_delay(prev_r, pr, blocks, cost, net, tau)
            prev_e, prev_r = pe, pr
        ratios.append(tot_r / tot_e)
    assert np.mean(ratios) <= 1.25, ratios     # 15-20% claim (+ margin)


def test_exact_horizon_beats_myopic_chain():
    blocks, cost, net = small_setup(n_heads=2, n_dev=2, seed=11,
                                    n_layers=32, compute_mode="incremental")
    nets = [net.copy() for _ in range(3)]
    _, v_h = exact_horizon(blocks, cost, nets)
    prev = None
    tot = 0.0
    for tau, n in enumerate(nets, start=1):
        p, v = exact_myopic(blocks, cost, n, tau, prev)
        tot += v
        prev = p
    assert v_h <= tot + 1e-9


# --------------------------------------------------------------- simulator
def test_paper_claim_medium_scale_ordering():
    """§V.D: resource-aware < galaxy < edgeshard in total latency, with
    several-fold speedup vs the pipeline baselines under K/V growth."""
    blocks = make_blocks(32)
    cost = CostModel(d_model=2048, n_heads=32, L0=64, n_layers=32,
                     compute_mode="incremental")
    net = DeviceNetwork.sample(25, seed=7)
    res = {}
    for name in ("resource-aware", "edgeshard", "galaxy"):
        kw = dict(deadline=0.2) if name == "resource-aware" else {}
        pol = ALL_POLICIES[name](blocks, cost, **kw)
        res[name] = simulate(pol, blocks, cost, net, 300, seed=11)
    ra = res["resource-aware"].total_latency
    assert ra < res["galaxy"].total_latency < res["edgeshard"].total_latency
    assert res["edgeshard"].total_latency / ra > 2.0


def test_memory_overload_regime_speedup():
    """Tight memory (the paper's Fig.3/4 regime): ~an order of magnitude
    vs EdgeShard as its static shard overflows."""
    blocks = make_blocks(32)
    cost = CostModel(d_model=2048, n_heads=32, L0=64, n_layers=32,
                     compute_mode="incremental")
    net = DeviceNetwork.sample(25, seed=7,
                               mem_range=(1 * GB, 3 * GB))
    ra = simulate(ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.2),
                  blocks, cost, net, 400, seed=11)
    es = simulate(ALL_POLICIES["edgeshard"](blocks, cost),
                  blocks, cost, net, 400, seed=11)
    # grows to ~6x at N=1000 (benchmarks/latency_vs_tokens.py, Fig. 3)
    assert es.total_latency / ra.total_latency > 2.5
    assert ra.mem_max_series[-1] < es.mem_max_series[-1]


def test_lookahead_beats_or_matches_myopic():
    """Beyond-paper (the paper's §VI future work): EWMA+trend forecast of
    C_j(τ) with horizon-amortized migration costs nets out at least as fast
    as the myopic controller on the medium-scale scenario."""
    from repro.core.baselines import LookaheadPolicy
    blocks = make_blocks(32)
    cost = CostModel(d_model=2048, n_heads=32, L0=64, n_layers=32,
                     compute_mode="incremental")
    net = DeviceNetwork.sample(25, seed=7)
    ra = simulate(ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.2),
                  blocks, cost, net, 300, seed=11)
    la = simulate(LookaheadPolicy(blocks, cost, deadline=0.2),
                  blocks, cost, net, 300, seed=11)
    assert la.total_latency <= ra.total_latency * 1.05


# --------------------------------------- candidate-loop scoring regression
def test_assign_candidate_loop_uses_one_scoring_convention():
    """Regression (PR 1): the candidate list is sorted by the LOAD-AWARE
    score, but the old early-exit recomputed a load-blind score and
    ``break``-ed on s > 1.0 assuming the list was sorted by that same
    quantity.  With hysteresis discounting the previous device, an
    individually-infeasible prev device can sort FIRST — the old break then
    skipped every feasible device behind it, bouncing the block through
    ResolveResourceOverload with inconsistent migration accounting.

    Construct exactly that: prev holds everything on device 0, whose
    compute has degraded so the ffn's raw score there is 1.05 (> 1,
    infeasible) but 0.945 after the 0.9 hysteresis discount — sorting it
    ahead of device 1 at 0.99 (feasible).  The fixed loop must place the
    ffn on device 1 via the primary path, with stats.migrations equal to
    the number of blocks that actually moved."""
    n_heads = 8
    blocks = make_blocks(n_heads)
    cost = CostModel(d_model=512, n_heads=n_heads, L0=64, lam=1)
    ffn = next(b for b in blocks if b.kind == FFN)
    ffn_comp = cost.compute(ffn, 1)
    C0 = ffn_comp / 1.05          # raw score on dev0: 1.05 (infeasible)
    C1 = ffn_comp / 0.99          # raw score on dev1: 0.99 (feasible)
    bw = np.full((2, 2), 1e12)
    np.fill_diagonal(bw, np.inf)
    net = DeviceNetwork(mem_capacity=np.array([4.0 * GB, 4.0 * GB]),
                        compute_max=np.array([C0, C1]),
                        compute_avail=np.array([C0, C1]),
                        bandwidth=bw, controller=0,
                        rng=np.random.default_rng(0))
    prev = np.zeros(len(blocks), dtype=int)
    assigner = ResourceAwareAssigner(blocks, cost, deadline=1.0,
                                     objective_tiebreak=False)
    place, stats = assigner.assign(net, 1, prev)
    assert place is not None and not stats.infeasible
    assert place[ffn.index] == 1          # feasible device was NOT skipped
    # heads + proj stay put: only the ffn migrates, and the stats agree
    moved = int((place != prev).sum())
    assert moved == 1
    assert stats.migrations == moved
