"""Self-tests for the hot-path auditor (repro.analysis).

Three layers:
  1. seeded-violation fixtures (tests/fixtures/rpr, tests/fixtures/hlo)
     each FAIL their pass — the auditor's rules actually fire;
  2. the live repo audits CLEAN — the gate in scripts/ci.sh lands green;
  3. the satellite fixes hold: the engine's decode jit donates (aliased
     cache outputs, zero full-cache parameter copies) with the token
     stream bit-identical to the undonated jit, and RestartPolicy
     records WHAT failed, not just that something failed.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Finding, hlo_audit, jaxpr_audit
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.lints import iter_python_files, lint_paths, lint_source

REPO = pathlib.Path(__file__).resolve().parents[1]
LINT_FIXTURE = REPO / "tests" / "fixtures" / "rpr" / "lint_violations.py"
HLO_FIXTURES = REPO / "tests" / "fixtures" / "hlo"
LINT_ROOTS = [str(REPO / p) for p in
              ("src", "benchmarks", "examples", "tests", "scripts")]


def _codes(findings):
    out = {}
    for f in findings:
        out[f.code] = out.get(f.code, 0) + 1
    return out


# ------------------------------------------------------------ RPR lint pass
class TestLintFixture:
    def test_every_seeded_violation_fires(self):
        found = _codes(lint_source(LINT_FIXTURE.read_text(),
                                   str(LINT_FIXTURE)))
        assert found == {
            "RPR000": 1,  # reasonless waiver
            "RPR001": 2,  # in-loop key + counter-attribute key
            "RPR002": 1,  # env drops JAX_PLATFORMS
            "RPR003": 2,  # unbound + bound-but-unused broad except
            "RPR004": 1,  # int() sync inside the decode loop
            "RPR005": 1,  # undonated stateful jit
        }

    def test_fixture_excluded_from_directory_scan(self):
        files = iter_python_files([str(REPO / "tests")])
        assert LINT_FIXTURE not in files
        # ...but lintable when named explicitly (how this test reads it)
        assert iter_python_files([str(LINT_FIXTURE)]) == [LINT_FIXTURE]

    def test_waiver_with_reason_suppresses(self):
        src = ("import jax\n"
               "def f(xs):\n"
               "    for x in xs:\n"
               "        k = jax.random.PRNGKey(0)"
               "  # rpr: ignore[RPR001] -- test corpus needs a fixed key\n"
               "        yield k\n")
        assert lint_source(src) == []

    def test_waiver_wrong_code_does_not_suppress(self):
        src = ("import jax\n"
               "def f(xs):\n"
               "    for x in xs:\n"
               "        k = jax.random.PRNGKey(0)"
               "  # rpr: ignore[RPR005] -- mismatched code\n"
               "        yield k\n")
        assert "RPR001" in _codes(lint_source(src))

    def test_bare_raise_handler_is_not_swallowing(self):
        src = ("def f(fn):\n"
               "    try:\n"
               "        return fn()\n"
               "    except Exception:\n"
               "        raise\n")
        assert lint_source(src) == []

    def test_env_spread_is_clean(self):
        src = ("import os, subprocess\n"
               "def f(cmd):\n"
               "    return subprocess.run(cmd,"
               " env={**os.environ, 'X': '1'})\n")
        assert lint_source(src) == []

    def test_repo_lints_clean(self):
        # the CI gate: every violation in the live tree is fixed or waived
        assert lint_paths(LINT_ROOTS) == []


# ---------------------------------------------------------- jaxpr audit pass
class TestJaxprAudit:
    def test_jxp001_implicit_promotion_on_big_array(self):
        def f(cache, upd):
            return cache + upd  # bf16 + f32 silently widens the cache

        jx = jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
            jax.ShapeDtypeStruct((128, 128), jnp.float32))
        assert "JXP001" in _codes(jaxpr_audit.audit_jaxpr(jx, "f"))

    def test_jxp001_found_inside_scan_body(self):
        def f(cache):
            def body(c, _):
                return c, c.astype(jnp.float32)
            _, ys = jax.lax.scan(body, cache, None, length=2)
            return ys

        jx = jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((64, 256), jnp.bfloat16))
        assert "JXP001" in _codes(jaxpr_audit.audit_jaxpr(jx, "f"))

    def test_jxp001_narrowing_is_fine(self):
        def f(x):
            return x.astype(jnp.bfloat16)

        jx = jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((128, 128), jnp.float32))
        assert jaxpr_audit.audit_jaxpr(jx, "f") == []

    def test_jxp002_host_callback(self):
        def f(x):
            jax.debug.print("x={x}", x=x.sum())
            return x * 2

        jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((8,), jnp.float32))
        assert "JXP002" in _codes(jaxpr_audit.audit_jaxpr(jx, "f"))

    def test_jxp003_closure_captured_constant(self):
        baked = np.ones((128, 128), np.float32)

        def f(x):
            return x + baked

        jx = jax.make_jaxpr(f)(
            jax.ShapeDtypeStruct((128, 128), jnp.float32))
        assert "JXP003" in _codes(jaxpr_audit.audit_jaxpr(jx, "f"))

    def test_hot_functions_audit_clean(self):
        assert jaxpr_audit.audit_hot_functions() == []


# ------------------------------------------------------------ HLO audit pass
CACHE_BYTES = 2 * 2 * 64 * 4 * 16 * 2  # audit-tiny bf16 KV cache


class TestHloAuditFixtures:
    def test_planted_donation_failure_fires_both_rules(self):
        txt = (HLO_FIXTURES / "donation_failure.hlo").read_text()
        found = _codes(hlo_audit.audit_decode_hlo(txt, CACHE_BYTES))
        assert found == {"HLO001": 1, "HLO002": 1}

    def test_aliased_in_place_module_is_clean(self):
        txt = (HLO_FIXTURES / "donation_ok.hlo").read_text()
        assert hlo_audit.audit_decode_hlo(txt, CACHE_BYTES) == []


class TestHloAuditLive:
    def test_engine_decode_jit_donates(self):
        # the satellite fix: the engine's OWN decode jit must alias the
        # cache outputs and copy nothing parameter-derived at cache size
        s = hlo_audit.build_audit_setup()
        cb = hlo_audit.cache_bytes_of(s["state"])
        assert hlo_audit.audit_decode_hlo(hlo_audit.decode_hlo_text(),
                                          cb) == []

    def test_undonated_decode_jit_is_flagged(self):
        # the pre-fix defect, reconstructed: jit without donate_argnums
        s = hlo_audit.build_audit_setup()
        txt = jax.jit(s["model"].decode_step).lower(
            s["params"], s["state"], s["tokens"]).compile().as_text()
        found = _codes(hlo_audit.audit_decode_hlo(
            txt, hlo_audit.cache_bytes_of(s["state"])))
        assert found.get("HLO001", 0) >= 2  # k and v caches both unaliased

    def test_donation_streams_bit_identical(self):
        s = hlo_audit.build_audit_setup()
        m, params = s["model"], s["params"]
        donated = jax.jit(m.decode_step, donate_argnums=(1,))
        # rpr: ignore[RPR005] -- reference jit: proves donation changes
        # nothing but buffer reuse
        undonated = jax.jit(m.decode_step)

        def run(step):
            state = jax.tree_util.tree_map(
                lambda x: jnp.array(x, copy=True), s["state"])
            toks = jnp.zeros((2,), jnp.int32)
            outs = []
            for _ in range(4):
                logits, state = step(params, state, toks)
                toks = jnp.argmax(logits, -1).astype(jnp.int32)
                outs.append(toks)
            return np.stack([np.asarray(t) for t in outs])

        np.testing.assert_array_equal(run(donated), run(undonated))

    def test_prefill_ladder_bounded(self):
        ladder = hlo_audit.prefill_ladder()
        assert ladder["prefill_lowerings"] == ladder["n_buckets"]
        assert ladder["insert_lowerings"] == 1

    def test_budgets_fail_closed_on_missing_file(self, tmp_path):
        found = hlo_audit.audit_budgets(tmp_path / "absent.json")
        assert len(found) == 1 and found[0].code == "HLO004"
        assert "--update-baselines" in found[0].message

    def test_budgets_fail_closed_on_missing_key(self, tmp_path):
        p = tmp_path / "partial.json"
        p.write_text(json.dumps({"decode_step": {"dot_flops": 1e12}}))
        found = hlo_audit.audit_budgets(p)
        assert found and all(f.code == "HLO004" for f in found)
        assert any("hbm_bytes" in f.where for f in found)

    def test_budgets_catch_regression(self, tmp_path):
        p = tmp_path / "tight.json"
        p.write_text(json.dumps(
            {"decode_step": {k: 0.0 for k in hlo_audit.TOLERANCES}}))
        found = hlo_audit.audit_budgets(p)
        assert any(f.code == "HLO004" and "dot_flops" in f.where
                   for f in found)

    def test_committed_baselines_pass(self):
        assert hlo_audit.BASELINES_PATH.exists()
        assert hlo_audit.audit_budgets() == []

    def test_full_hlo_pass_clean(self):
        assert hlo_audit.audit_compiled_hot_path() == []


# ------------------------------------------------------------------- the CLI
class TestCli:
    def test_lint_pass_clean_repo_exits_zero(self, capsys):
        rc = analysis_main(["lint", "--paths"] + LINT_ROOTS)
        assert rc == 0
        assert "lint: clean" in capsys.readouterr().out

    def test_lint_pass_fixture_exits_nonzero(self, capsys):
        rc = analysis_main(["lint", "--paths", str(LINT_FIXTURE)])
        assert rc == 1
        out = capsys.readouterr().out
        assert "RPR005" in out and "FAILED" in out

    def test_unknown_pass_rejected(self):
        with pytest.raises(SystemExit):
            analysis_main(["hlo2"])

    def test_json_output_is_parseable(self, capsys):
        rc = analysis_main(
            ["lint", "--json", "--paths", str(LINT_FIXTURE)])
        assert rc == 1
        rows = json.loads(capsys.readouterr().out)
        assert {"code", "where", "message"} <= set(rows[0])


# ------------------------------------------- satellite: fault event logging
class TestRestartPolicyEvents:
    def test_fault_cause_is_recorded(self):
        from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                                   RestartPolicy)

        class Ckpt:
            def latest_step(self):
                return 7

        calls = []

        def train_fn(resume):
            calls.append(resume)
            if len(calls) < 3:
                raise RuntimeError(f"device OOM on attempt {len(calls)}")

        mon = HeartbeatMonitor(2)
        pol = RestartPolicy(Ckpt(), max_retries=3, backoff_s=0.0,
                            monitor=mon)
        pol.run(train_fn)
        assert calls == [7, 7, 7]
        assert len(pol.events) == 2
        ev = pol.events[0]
        assert ev["error_type"] == "RuntimeError"
        assert "device OOM on attempt 1" in ev["error"]
        assert ev["resume_step"] == 7
        # mirrored into the monitor's log for post-mortems
        assert [e["kind"] for e in mon.events] == ["worker_fault"] * 2

    def test_exhausted_retries_reraise_with_events(self):
        from repro.runtime.fault_tolerance import RestartPolicy

        class Ckpt:
            def latest_step(self):
                return None

        def train_fn(resume):
            raise ValueError("persistent corruption")

        pol = RestartPolicy(Ckpt(), max_retries=1, backoff_s=0.0)
        with pytest.raises(ValueError):
            pol.run(train_fn)
        assert len(pol.events) == 2
        assert all(e["error_type"] == "ValueError" for e in pol.events)


def test_finding_str():
    f = Finding("RPR001", "x.py:3", "key reuse")
    assert str(f) == "RPR001 x.py:3: key reuse"
