"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps
per the assignment — every kernel allclose against ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_kernel import rwkv6_chunked

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KvE,S,dh,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),     # MHA
    (2, 8, 2, 256, 64, 128, 64),    # GQA 4:1
    (1, 4, 1, 128, 128, 64, 128),   # MQA, dh=128
    (2, 2, 2, 192, 32, 64, 96),     # uneven blocks
])
def test_flash_attention_causal(dtype, B, H, KvE, S, dh, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), dtype)
    k = jax.random.normal(ks[1], (B, KvE, S, dh), dtype)
    v = jax.random.normal(ks[2], (B, KvE, S, dh), dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [32, 100])
def test_flash_attention_sliding_window(window):
    B, H, KvE, S, dh = 2, 4, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, KvE, S, dh))
    v = jax.random.normal(ks[2], (B, KvE, S, dh))
    out = flash_attention(q, k, v, causal=True, window=window,
                          bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    B, H, KvE, S, dh = 1, 2, 2, 128, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, KvE, S, dh))
    v = jax.random.normal(ks[2], (B, KvE, S, dh))
    out = flash_attention(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KvE,T,dh,bk", [
    (2, 8, 4, 256, 64, 64),
    (3, 4, 1, 128, 128, 128),
    (1, 2, 2, 512, 32, 256),
])
def test_decode_attention(dtype, B, H, KvE, T, dh, bk):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, KvE, T, dh), dtype)
    v = jax.random.normal(ks[2], (B, KvE, T, dh), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = decode_attention(q, k, v, lens, bk=bk, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_decode_attention_skips_invalid_blocks():
    """Length-masked region must not contribute even if it contains junk."""
    B, H, KvE, T, dh = 1, 2, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, KvE, T, dh))
    v = jax.random.normal(ks[2], (B, KvE, T, dh))
    k = k.at[:, :, 100:].set(1e9)  # poison the invalid tail
    v = v.at[:, :, 100:].set(1e9)
    lens = jnp.array([100])
    out = decode_attention(q, k, v, lens, bk=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lens)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (2, 3, 64, 16, 16),
    (1, 2, 128, 32, 32),
    (2, 1, 96, 64, 96),
])
def test_rwkv6_kernel(B, H, S, dh, chunk):
    ks = jax.random.split(KEY, 5)
    r = 0.5 * jax.random.normal(ks[0], (B, H, S, dh))
    k = 0.5 * jax.random.normal(ks[1], (B, H, S, dh))
    v = 0.5 * jax.random.normal(ks[2], (B, H, S, dh))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, dh))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(ks[4], (H, dh))
    s0 = 0.1 * jax.random.normal(KEY, (B, H, dh, dh))
    y, sT = rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y_ref, sT_ref = ref.rwkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-5, rtol=1e-5)


def test_rwkv6_kernel_state_chaining():
    """Two chunked calls == one long call (state carry correctness)."""
    B, H, S, dh = 1, 2, 64, 16
    ks = jax.random.split(KEY, 5)
    mk = lambda i: 0.4 * jax.random.normal(ks[i], (B, H, S, dh))
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, dh))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (H, dh))
    s0 = jnp.zeros((B, H, dh, dh))
    y_full, s_full = rwkv6_chunked(r, k, v, w, u, s0, chunk=32, interpret=True)
    half = S // 2
    y1, s1 = rwkv6_chunked(r[:, :, :half], k[:, :, :half], v[:, :, :half],
                           w[:, :, :half], u, s0, chunk=32, interpret=True)
    y2, s2 = rwkv6_chunked(r[:, :, half:], k[:, :, half:], v[:, :, half:],
                           w[:, :, half:], u, s1, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=2)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-5, rtol=1e-5)


def test_model_rwkv_with_kernel_matches_scan(rng_key):
    """RWKV6Model(use_kernel=True) == pure-scan model output."""
    from tests.conftest import reduced_config
    from repro.models.api import build_model
    cfg = reduced_config("rwkv6-7b")
    m_scan = build_model(cfg)
    m_kern = build_model(cfg, use_kernel=True)
    params = m_scan.init(rng_key)
    toks = jax.random.randint(rng_key, (2, 32), 0, cfg.vocab_size)
    l1, _ = m_scan.forward(params, toks)
    l2, _ = m_kern.forward(params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("B,H,KvE,T,dh,bk", [
    (2, 4, 2, 256, 64, 64),
    (1, 4, 4, 128, 32, 128),
])
def test_decode_attention_int8_fused(B, H, KvE, T, dh, bk):
    """Fused int8-KV flash-decode == dequantized-cache oracle (and within
    quantization error of the fp32 cache)."""
    from repro.kernels.decode_attention import decode_attention_int8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, KvE, T, dh))
    v = jax.random.normal(ks[2], (B, KvE, T, dh))

    def q8(t):
        sc = jnp.maximum(jnp.abs(t).max(-1), 1e-8) / 127.0
        qq = jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
        return qq.astype(jnp.int8), sc

    kq, ksc = q8(k)
    vq, vsc = q8(v)
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = decode_attention_int8(q, kq, ksc, vq, vsc, lens, bk=bk,
                                interpret=True)
    kd = kq.astype(jnp.float32) * ksc[..., None]
    vd = vq.astype(jnp.float32) * vsc[..., None]
    want = ref.decode_attention_ref(q, kd, vd, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    full = ref.decode_attention_ref(q, k, v, lens)
    assert float(jnp.abs(out - full).max()) < 0.05  # int8 quantization error
