"""Seeded RPR violations — the lint self-test corpus.

Never linted by the repo gate (``fixtures`` is in
``repro.analysis.lints.EXCLUDED_PARTS``); ``tests/test_analysis.py``
lints this file explicitly and asserts every rule fires exactly where
planted.  Each function is one violation class, deliberately wrong.
"""
import subprocess

import jax
import numpy as np


def reused_key_in_loop(n):
    # RPR001: the same key every iteration — every "sample" is identical
    outs = []
    for _ in range(n):
        key = jax.random.PRNGKey(0)
        outs.append(jax.random.normal(key, (4,)))
    return outs


class Sampler:
    def counter_key(self):
        # RPR001: keys off a mutable counter — collides across call sites
        return jax.random.PRNGKey(self.decode_steps)


def child_without_platforms(cmd):
    # RPR002: literal env drops JAX_PLATFORMS — the child jax probes
    # accelerator plugins and hangs
    return subprocess.run(cmd, env={"PATH": "/usr/bin"})


def swallow(fn):
    try:
        fn()
    except Exception:
        # RPR003: nothing bound, nothing recorded
        return None


def swallow_bound_unused(fn):
    try:
        fn()
    except Exception as e:
        # RPR003: binds `e` but never records it
        return None


def decode_loop(model, params, state, tok):
    step = jax.jit(model.decode_step)  # RPR005: no donate_argnums
    for _ in range(8):
        logits, state = step(params, state, tok)
        tok = int(np.argmax(logits))  # RPR004: host sync per step
    return tok


def waived_without_reason(fn):
    try:
        fn()
    except Exception:  # rpr: ignore[RPR003]
        return None  # the waiver above is reasonless -> RPR000


def properly_waived(fn):
    try:
        fn()
    # rpr: ignore[RPR003] -- fixture: a reasoned waiver must suppress
    except Exception:
        return None
