"""Bottleneck-targeted pipeline placement search: never worse than the
PR-3 rescoring policy on D_pipe(K) (hypothesis sweep), K=1 bit-for-bit
the paper algorithm, solver parity hooks, attribution/seed primitives,
and the engine roundtrip where a bottleneck-mode plan physically
migrates."""
import numpy as np
import pytest

from repro.core import (ALL_POLICIES, BottleneckAwarePolicy, CostModel,
                        DeviceNetwork, ResourceAwarePolicy,
                        bottleneck_attribution, inference_delay,
                        make_blocks, memory_feasible, pipeline_bottleneck,
                        pipelined_inference_delay, refine_bottleneck,
                        resource_busy_times, simulate, stage_balanced_chain,
                        total_delay)
from repro.core.blocks import graph_of
from repro.core.network import GBPS
from repro.core.solver import exact_myopic


def _setup(n_heads=4, n_layers=3, n_dev=4, seed=3, bw=(0.05, 2.0)):
    blocks = make_blocks(n_heads, n_layers)
    cost = CostModel(d_model=1024, n_heads=n_heads, n_layers=n_layers,
                     layer_mode="graph", compute_mode="incremental")
    net = DeviceNetwork.sample(n_dev, seed=seed,
                               bw_range=(bw[0] * GBPS, bw[1] * GBPS))
    return blocks, cost, net


# ------------------------------------------------ attribution primitive
def test_bottleneck_attribution_is_the_argmax_resource():
    blocks, cost, net = _setup()
    rng = np.random.default_rng(0)
    for tau in (1, 9, 40):
        place = rng.integers(0, net.n_devices, len(blocks))
        kind, ident, busy = bottleneck_attribution(blocks=blocks, cost=cost,
                                                   net=net, tau=tau,
                                                   place=place)
        assert np.isclose(busy,
                          pipeline_bottleneck(place, blocks, cost, net, tau))
        dev_busy, link_busy = resource_busy_times(place, blocks, cost, net,
                                                  tau)
        if kind == "device":
            assert np.isclose(busy, dev_busy[ident])
        else:
            assert np.isclose(busy, link_busy[ident])
            assert ident[0] != ident[1]


# ----------------------------------------------------- chain seed shape
def test_stage_balanced_chain_is_contiguous_and_feasible():
    blocks, cost, net = _setup(n_layers=4, n_dev=3)
    place = stage_balanced_chain(blocks, cost, net, 2, pipeline_k=4)
    assert place is not None
    assert memory_feasible(place, blocks, cost, net, 2)
    g = graph_of(blocks)
    # one device per layer, contiguous runs: the device sequence over
    # layers never revisits a device after leaving it
    devs = []
    for l in range(g.n_layers):
        layer_devs = {int(place[b.index]) for b in g.layer_blocks(l)}
        assert len(layer_devs) == 1, f"layer {l} split across {layer_devs}"
        devs.append(layer_devs.pop())
    seen = set()
    for i, d in enumerate(devs):
        if i and d != devs[i - 1]:
            assert d not in seen, f"chain revisits device {d}"
        seen.add(d)


# ------------------------------------------- refinement is D_pipe-monotone
def test_refine_bottleneck_never_raises_dpipe():
    blocks, cost, net = _setup()
    rng = np.random.default_rng(1)
    for k in (2, 8):
        for _ in range(3):
            place = rng.integers(0, net.n_devices, len(blocks))
            prev = rng.integers(0, net.n_devices, len(blocks))
            before = pipelined_inference_delay(place, blocks, cost, net, 5,
                                               k=k)
            out = refine_bottleneck(prev, place, blocks, cost, net, 5, k=k)
            after = pipelined_inference_delay(out, blocks, cost, net, 5, k=k)
            assert after <= before * (1 + 1e-12)
            assert memory_feasible(out, blocks, cost, net, 5) or \
                not memory_feasible(place, blocks, cost, net, 5)


# --------------------------------------------------- K=1 is the paper algo
def test_k1_bit_for_bit_equals_resource_aware():
    """search="bottleneck" with pipeline_k=1 IS the paper algorithm: the
    search only exists on the pipelined objective."""
    blocks, cost, net = _setup(seed=7)
    ra = ResourceAwarePolicy(blocks, cost, deadline=0.5)
    bn = BottleneckAwarePolicy(blocks, cost, deadline=0.5)
    prev_a = prev_b = None
    for tau in range(1, 6):
        net.step_background_load() if tau > 1 else None
        pa = ra.place(net, tau, prev_a)
        pb = bn.place(net, tau, prev_b)
        assert np.array_equal(pa, pb), f"tau={tau}"
        prev_a, prev_b = pa, pb


def test_search_mode_validated():
    blocks, cost, net = _setup()
    with pytest.raises(ValueError, match="search must be one of"):
        ResourceAwarePolicy(blocks, cost, search="annealing")
    # the controller path validates too — a typo must fail at
    # construction, not silently serve the rescoring planner
    from repro.core.controller import ControllerConfig, IntervalController
    with pytest.raises(ValueError, match="search must be one of"):
        IntervalController(4, cost, net,
                           ControllerConfig(search="Bottleneck",
                                            pipeline_k=2))


def test_exact_horizon_infeasible_returns_empty_not_garbage():
    from repro.core.solver import exact_horizon
    blocks = make_blocks(1, 1)
    cost = CostModel(d_model=256, n_heads=1)
    net = DeviceNetwork.sample(2, seed=0)
    net.mem_capacity = net.mem_capacity * 0.0   # nothing fits anywhere
    path, total = exact_horizon(blocks, cost, [net, net])
    assert path == [] and total == np.inf


# ------------------------------------------- never worse than rescoring
def test_bottleneck_never_worse_dpipe_hypothesis():
    """Acceptance sweep: on random feasible topologies the bottleneck-
    targeted search never returns a placement whose D_pipe(K) is worse
    than the PR-3 rescoring policy's, with or without migration history."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3),
           st.integers(2, 4), st.integers(2, 4), st.sampled_from([2, 4, 8]))
    def check(seed, n_layers, n_heads, n_dev, k):
        blocks = make_blocks(n_heads, n_layers)
        cost = CostModel(d_model=512, n_heads=n_heads, n_layers=n_layers,
                         layer_mode="graph", compute_mode="incremental")
        net = DeviceNetwork.sample(n_dev, seed=seed % 10_000,
                                   bw_range=(0.02 * GBPS, 4 * GBPS))
        ra = ResourceAwarePolicy(blocks, cost, deadline=0.5, pipeline_k=k)
        bn = BottleneckAwarePolicy(blocks, cost, deadline=0.5, pipeline_k=k)
        prev = None
        for tau in (1, 2):
            pa = ra.place(net, tau, prev)
            pb = bn.place(net, tau, prev)
            if pa is None or pb is None:
                return
            da = pipelined_inference_delay(pa, blocks, cost, net, tau, k=k)
            db = pipelined_inference_delay(pb, blocks, cost, net, tau, k=k)
            assert db <= da * (1 + 1e-9) + 1e-15, (tau, db, da)
            # both arms continue from the BOTTLENECK stream's history so
            # the comparison stays a same-prev, same-net one
            prev = pb

    check()


def test_bottleneck_policy_beats_rescoring_under_straggle():
    """The headline mechanism: a mid-stream straggler wedges the rescoring
    policy (one-interval migration payback refuses the rescue move) while
    the amortized bottleneck search migrates off and re-balances."""
    blocks, cost, net0 = _setup(n_heads=4, n_layers=4, n_dev=4, seed=11)
    k = 8

    def drive(policy_name):
        net = net0.copy()
        pol = ALL_POLICIES[policy_name](blocks, cost, deadline=0.5,
                                        pipeline_k=k)
        prev, total = None, 0.0
        from repro.core.delay import migration_delay
        for tau in range(1, 25):
            if tau == 5:
                # straggle the busiest compute device
                dev_busy, _ = resource_busy_times(prev, blocks, cost, net,
                                                  tau)
                net.inject_straggler(int(np.argmax(dev_busy)), 25.0)
            place = pol.place(net, tau, prev)
            total += pipelined_inference_delay(place, blocks, cost, net,
                                               tau, k=k)
            total += migration_delay(prev, place, blocks, cost, net, tau)
            prev = place
        return total

    t_ra = drive("resource-aware")
    t_bn = drive("bottleneck-aware")
    assert t_bn < t_ra, (t_bn, t_ra)


# ------------------------------------------------- solver parity hooks
def test_exact_myopic_bottleneck_objective():
    blocks = make_blocks(2, 2)
    cost = CostModel(d_model=512, n_heads=2, n_layers=2, layer_mode="graph",
                     compute_mode="incremental")
    net = DeviceNetwork.sample(3, seed=5, bw_range=(0.5 * GBPS, 5 * GBPS))
    p_d, v_d = exact_myopic(blocks, cost, net, 3, None)
    p_b, v_b = exact_myopic(blocks, cost, net, 3, None,
                            objective="bottleneck")
    assert p_b is not None
    b_of = lambda p: min(pipeline_bottleneck(p, blocks, cost, net, 3),
                         inference_delay(p, blocks, cost, net, 3))
    # the bottleneck optimum's busy time is <= any other placement's,
    # including the delay optimum's
    assert v_b <= b_of(p_d) + 1e-12
    assert np.isclose(v_b, b_of(p_b))
    # tie-break: among equal-B placements the solver picked a minimal
    # D_T + D_mig one — re-enumerate to verify
    from repro.core.solver import _all_placements
    best_tie = min(total_delay(None, p, blocks, cost, net, 3)
                   for p in _all_placements(len(blocks), net.n_devices)
                   if memory_feasible(p, blocks, cost, net, 3)
                   and b_of(p) <= v_b + 1e-15)
    assert total_delay(None, p_b, blocks, cost, net, 3) <= best_tie + 1e-12
    with pytest.raises(ValueError, match="objective"):
        exact_myopic(blocks, cost, net, 3, None, objective="nope")


def test_exact_horizon_bottleneck_objective():
    from repro.core.solver import exact_horizon
    blocks = make_blocks(1, 1)
    cost = CostModel(d_model=256, n_heads=1)
    nets = [DeviceNetwork.sample(3, seed=s) for s in (1, 2)]
    path_d, v_d = exact_horizon(blocks, cost, nets)
    path_b, v_b = exact_horizon(blocks, cost, nets, objective="bottleneck")
    assert len(path_b) == 2
    # steady-state objective never exceeds the delay objective: B <= D_T
    assert v_b <= v_d + 1e-12
    with pytest.raises(ValueError, match="objective"):
        exact_horizon(blocks, cost, nets, objective="nope")


# -------------------------------------------------- simulator recording
def test_simulator_records_bottleneck_series():
    blocks, cost, net = _setup(n_layers=2, n_dev=3)
    pol = ALL_POLICIES["bottleneck-aware"](blocks, cost, deadline=0.5,
                                           pipeline_k=4)
    res = simulate(pol, blocks, cost, net, 4, seed=0, fluctuate=False,
                   pipeline_k=4)
    assert (res.bottleneck_series > 0).all()
    # the clamped bottleneck bounds the pipelined per-step delay from below
    for s in res.steps:
        assert s.d_inf >= min(s.d_bneck, s.d_inf) - 1e-15
    pol1 = ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.5)
    res1 = simulate(pol1, blocks, cost, net, 3, seed=0, fluctuate=False)
    assert (res1.bottleneck_series == 0).all()   # k=1: not a pipelined run


# ----------------------------------------- engine roundtrip (real plans)
def test_engine_bottleneck_mode_migrates_with_streams_equal():
    """A bottleneck-mode controller plan physically migrates cache+weights
    mid-serve (straggler injected) and the generated streams equal the
    migration-free sequential run — the new search drives REAL migrations,
    not just simulator scores."""
    pytest.importorskip("jax")
    from tests.conftest import reduced_config
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (4, 9, 6, 11)]

    def drive(k, lam, search, straggle_at=None):
        eng = ServingEngine(cfg, n_slots=4, max_seq=48, lam=lam, seed=0,
                            pipeline_k=k, search=search,
                            net=DeviceNetwork.sample(4, seed=1))
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                dev = int(eng.controller.head_counts().argmax())
                eng.net.inject_straggler(dev, slowdown=500.0)
            if not eng.step():
                break
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    seq, _ = drive(1, 10 ** 9, "rescoring")
    pipe, eng = drive(2, 3, "bottleneck", straggle_at=6)
    assert seq == pipe and len(pipe) == 4
    assert eng.controller._policy is not None          # plans from the mode
    assert eng.controller._policy.search == "bottleneck"
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "bottleneck-mode migration was skipped, not applied"
    assert all(e["reason"] is None for e in applied)
