"""Elastic device churn: network fail/slow/join/rejoin semantics, the
controller's evacuation/expansion plans, and the serving engine's
mid-decode recovery (teacher-forced replay => surviving streams are
bit-identical to a churn-free run, zero client-visible tokens lost)."""
import numpy as np
import pytest

from repro.core import DeviceNetwork
from repro.core.blocks import CostModel
from repro.core.controller import ControllerConfig, IntervalController
from repro.serving.async_runtime import AsyncServingEngine
from repro.serving.engine import ServingEngine
from repro.serving.workload import VirtualClock, drive_virtual, make_workload
from tests.conftest import reduced_config


# ---------------------------------------------------------------- network
def test_network_churn_transitions_and_errors():
    net = DeviceNetwork.sample(4, seed=0)
    net.fail(2)
    assert not net.is_active(2) and net.n_active == 3
    assert 2 not in net.active_ids
    assert net.compute_avail[2] == 0.0
    assert net.mem_usable()[2] == 0.0
    # slow on a dead device is a no-op; on a live one it pins load
    net.slow(2, 4.0)
    assert net.compute_avail[2] == 0.0
    net.slow(1, 4.0)
    assert net.compute_avail[1] == pytest.approx(net.compute_max[1] / 4.0)
    with pytest.raises(ValueError):
        net.slow(1, 0.5)
    # rejoin restores full, fresh capacity
    net.rejoin(2)
    assert net.is_active(2)
    assert net.compute_avail[2] == net.compute_max[2]
    # join appends a device with symmetric links
    j = net.join(1e9, 2e9, np.full(4, 1e8))
    assert j == 4 and net.n_devices == 5 and net.is_active(4)
    assert np.all(net.bandwidth[4, :4] == net.bandwidth[:4, 4])
    assert np.isinf(net.bandwidth[4, 4])
    with pytest.raises(ValueError):
        net.join(1e9, 2e9, np.full(3, 1e8))       # wrong bw_row length
    with pytest.raises(ValueError):
        net.join(-1.0, 2e9, np.full(5, 1e8))      # non-positive resources
    # background-load stepping skips inactive devices but keeps the rest
    net.fail(1)
    before = net.compute_avail[1]
    net.step_background_load()
    assert net.compute_avail[1] == before == 0.0


# ------------------------------------------------------------- controller
def _controller(net, n_heads=8, hps=2, lam=16):
    cost = CostModel(d_model=256, n_heads=n_heads, L0=8, lam=lam,
                     n_layers=2, layer_mode="graph",
                     compute_mode="incremental")
    return IntervalController(n_heads, cost, net,
                              ControllerConfig(lam=lam, heads_per_slot=hps))


def test_handle_failure_evacuates_dead_device():
    net = DeviceNetwork.sample(4, seed=1)
    ctl = _controller(net)
    ctl.step_interval()
    plan = ctl.handle_failure(2)
    assert plan["evacuation"] and plan["failed_device"] == 2
    assert not np.any(np.asarray(plan["place"]) == 2)
    assert not net.is_active(2)
    assert ctl.history[-1].get("evacuation") is True
    # a later interval still never places on the dead device
    plan2 = ctl.step_interval()
    assert not np.any(np.asarray(plan2["place"]) == 2)


def test_handle_failure_infeasible_raises():
    """Survivors that cannot hold the dead device's blocks must fail
    loudly, not silently keep serving from a corpse."""
    big, tiny = 1e12, 10.0
    net = DeviceNetwork(
        mem_capacity=np.array([big, tiny, tiny]),
        compute_max=np.full(3, 1e9), compute_avail=np.full(3, 1e9),
        bandwidth=np.where(np.eye(3, dtype=bool), np.inf, 1e9),
        rng=np.random.default_rng(0))
    ctl = _controller(net, n_heads=3, hps=1)
    ctl.step_interval()
    assert np.all(np.asarray(ctl.place) == 0)     # only device 0 fits
    with pytest.raises(RuntimeError, match="evacuation infeasible"):
        ctl.handle_failure(0)


def test_handle_rejoin_emits_expansion_plan():
    net = DeviceNetwork.sample(4, seed=1)
    ctl = _controller(net)
    ctl.step_interval()
    ctl.handle_failure(2)
    plan = ctl.handle_rejoin(2)
    assert plan["expansion"] and plan["rejoined_device"] == 2
    assert net.is_active(2)


# ----------------------------------------------------------------- engine
def _churn_run(cfg, churn, lam=4, paged=False, **ekw):
    """Run 5 staggered requests on 2 slots, firing ``churn`` (a
    {decode_step: fn(eng)} dict) as the scheduler crosses each step."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                        paged=paged, **ekw)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=6 + 3 * (i % 2))
    ev = dict(churn)
    while True:
        if eng.decode_steps in ev:
            ev.pop(eng.decode_steps)(eng)
        if not eng.step():
            break
    assert not ev, f"unfired churn events at steps {sorted(ev)}"
    return {r.rid: r.out_tokens for r in eng.finished}, eng


def test_fail_device_mid_decode_streams_bit_identical():
    """Kill a device while slots sit at unequal depths: the evacuation +
    teacher-forced replay must leave every surviving stream bit-identical
    to a run with no churn and no controller at all."""
    cfg = reduced_config("musicgen-large")      # MHA: physical migrations
    ref, _ = _churn_run(cfg, {}, lam=10 ** 9)
    out, eng = _churn_run(cfg, {4: lambda e: e.fail_device(2)})
    assert out == ref and len(out) == 5
    assert not eng.net.is_active(2)
    assert not np.any(np.asarray(eng.controller.place) == 2)
    rec = eng.recovery_log[0]
    assert rec["event"] == "fail" and rec["device"] == 2
    assert rec["tokens_lost"] == 0 and eng.tokens_lost == 0
    assert rec["replayed_slots"] >= 1
    assert rec["replay_prefills"] == rec["replayed_slots"]
    # both slots were mid-decode at step 4, so replay actually decoded
    assert rec["replay_steps"] >= 1
    with pytest.raises(ValueError):
        eng.fail_device(2)                      # already dead


def test_fail_then_rejoin_streams_bit_identical_paged():
    """Same churn through the paged engine: the rebuilt page tables and
    re-admitted allocator must reproduce the streams, and a later rejoin
    (expansion migrations copy KV from survivors — no replay) must not
    disturb them either."""
    cfg = reduced_config("musicgen-large")
    ref, _ = _churn_run(cfg, {}, lam=10 ** 9, paged=True, page_size=8)
    churn = {4: lambda e: e.fail_device(2),
             12: lambda e: e.rejoin_device(2)}
    out, eng = _churn_run(cfg, churn, paged=True, page_size=8)
    assert out == ref and len(out) == 5
    assert eng.net.is_active(2)
    events = [r["event"] for r in eng.recovery_log]
    assert events == ["fail", "rejoin"]
    for alloc in eng.allocators:
        alloc.check_invariants()
    with pytest.raises(ValueError):
        eng.rejoin_device(2)                    # already active


def test_slow_device_migrates_away_streams_unchanged():
    cfg = reduced_config("musicgen-large")
    ref, _ = _churn_run(cfg, {}, lam=10 ** 9)
    out, eng = _churn_run(cfg, {3: lambda e: e.slow_device(1, 50.0)},
                          lam=3)
    assert out == ref
    assert eng.net.compute_avail[1] < eng.net.compute_max[1] / 10


# ------------------------------------------------------------------ async
def test_async_hang_escalates_to_controller_replan():
    """worker_hung must do more than log: the escalation refreshes the
    controller's availability view and forces Algorithm 1 on the next
    scheduler step even under an effectively-infinite λ cadence."""
    cfg = reduced_config("llama3-8b")
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    clock = VirtualClock()
    rt = AsyncServingEngine(eng, heartbeat_timeout=5.0,
                            heartbeat_clock=clock.now)
    clock.advance(6.0)
    hung = rt.check_workers()
    assert hung == [rt.ADMISSION, rt.DECODE]
    assert eng._replan_pending
    kinds = [e["kind"] for e in rt.monitor.events]
    assert kinds.count("recovery_escalated") == 2
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=3)
    assert eng.step()
    assert len(eng.migration_log) == 1          # interval fired off-cadence
    assert not eng._replan_pending
    assert not rt.check_workers()               # one-shot transition


def test_async_escalation_can_be_disabled():
    cfg = reduced_config("llama3-8b")
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    clock = VirtualClock()
    rt = AsyncServingEngine(eng, heartbeat_timeout=5.0,
                            heartbeat_clock=clock.now,
                            escalate_hangs=False)
    clock.advance(6.0)
    assert rt.check_workers() == [rt.ADMISSION, rt.DECODE]
    assert not eng._replan_pending
    assert all(e["kind"] != "recovery_escalated" for e in rt.monitor.events)


# ----------------------------------------------------------------- driver
def test_drive_virtual_events_and_model_pricing():
    """Churn events fire at their virtual time, model-priced stepping is
    deterministic, and neither changes any token stream."""
    cfg = reduced_config("llama3-8b")
    reqs = make_workload(rate=0.3, horizon=40.0, seed=5)

    def build():
        return ServingEngine(cfg, n_slots=2, max_seq=64, lam=6, seed=0)

    fired = []
    ev = [(10.0, lambda e: fired.append(e.decode_steps))]
    base = drive_virtual(build(), reqs)
    r1 = drive_virtual(build(), reqs, events=ev, price_by_model=True)
    r2 = drive_virtual(build(), reqs, events=list(ev), price_by_model=True)
    assert len(fired) == 2                      # once per priced run
    assert r1["streams"] == r2["streams"] == base["streams"]
    for k in ("p50_ttft", "p99_ttft", "goodput", "t_end"):
        assert r1[k] == r2[k]


def test_drive_virtual_event_fires_in_idle_gap():
    """An event scheduled inside an idle gap (or after the last arrival)
    must still fire — idle time jumps to it."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(0)
    from repro.serving.workload import TimedRequest
    reqs = [TimedRequest(0.0, rng.integers(0, 97, size=5).astype(np.int32),
                         3)]
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    fired = []
    drive_virtual(eng, reqs, events=[(1000.0, lambda e: fired.append(1))])
    assert fired == [1]
