"""int8 weight-only + int8 KV-cache serving (beyond-paper §Perf levers)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.api import build_model
from repro.models.quantization import (dequantize_weight, quantize_params,
                                       quantize_weight)
from tests.conftest import reduced_config


def test_quantize_roundtrip_error_bound(rng_key):
    w = jax.random.normal(rng_key, (4, 64, 8, 16)) * 0.3   # stacked (L,...)
    q = quantize_weight(w, base_ndim=3)
    assert q["q8"].dtype == jnp.int8
    assert q["sc"].shape == (4, 16)                        # per (layer, last)
    wd = dequantize_weight(q, jnp.float32)
    rel = float(jnp.abs(w - wd).max() / jnp.abs(w).max())
    assert rel < 0.02


@pytest.mark.parametrize("arch,tol", [("llama3-8b", 0.08),
                                      ("musicgen-large", 0.08)])
def test_int8_weights_close_to_float(arch, tol, rng_key):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(rng_key)
    qp = quantize_params(params)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    lf, _ = m.forward(params, toks)
    lq, _ = m.forward(qp, toks)
    rel = float(jnp.abs(lf - lq).max() / (jnp.abs(lf).max() + 1e-9))
    assert rel < tol, rel


def test_int8_weights_moe_top1_agreement(rng_key):
    """Router decisions may flip under weight perturbation; gate on top-1
    agreement instead of logit error."""
    cfg = reduced_config("mixtral-8x7b")
    m = build_model(cfg)
    params = m.init(rng_key)
    qp = quantize_params(params)
    toks = jax.random.randint(rng_key, (2, 32), 0, cfg.vocab_size)
    lf, _ = m.forward(params, toks)
    lq, _ = m.forward(qp, toks)
    agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
    assert agree > 0.9, agree


def test_int8_kv_cache_decode(rng_key):
    cfg = reduced_config("llama3-8b").with_overrides(kv_quant=True)
    m = build_model(cfg)
    params = m.init(rng_key)
    B, S, G = 2, 9, 4
    toks = jax.random.randint(rng_key, (B, S + G), 0, cfg.vocab_size)
    full, _ = m.forward(params, toks)
    st = m.init_decode_state(params, B, S + G)
    assert st["cache"]["k"].dtype == jnp.int8
    assert "k_sc" in st["cache"]
    lg, st = m.prefill(params, st, toks[:, :S])
    errs = [float(jnp.abs(lg - full[:, S - 1]).max())]
    for g in range(G):
        lg, st = m.decode_step(params, st, toks[:, S + g])
        errs.append(float(jnp.abs(lg - full[:, S + g]).max()))
    assert max(errs) < 0.05, errs


def test_quantized_decode_state_and_steps_jit(rng_key):
    """Quantized params + int8 cache through jitted prefill/decode."""
    cfg = reduced_config("llama3-8b").with_overrides(kv_quant=True)
    m = build_model(cfg)
    qp = quantize_params(m.init(rng_key))
    toks = jax.random.randint(rng_key, (2, 12), 0, cfg.vocab_size)
    st = m.init_decode_state(qp, 2, 24)
    lg, st = jax.jit(m.prefill, donate_argnums=(1,))(qp, st, toks)
    lg2, st = jax.jit(m.decode_step, donate_argnums=(1,))(
        qp, st, jnp.argmax(lg, -1))
    assert lg2.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all())
