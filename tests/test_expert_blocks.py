"""Expert-level block graph: collapse equivalence of the cost/delay model,
physical expert migration/replication invariance of the model function,
and the end-to-end expert-migration roundtrip through the serving engine.
"""
import numpy as np
import pytest

from benchmarks.paper_setup import layered_cost, layered_net
from repro.core.blocks import make_blocks, replicate_placement
from repro.core.delay import (inference_delay, pipelined_inference_delay,
                              resource_busy_times)
from repro.core.network import DeviceNetwork

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


# --------------------------------------------------- block-set identities
def test_single_expert_blocks_are_dense_blocks():
    """n_experts of 0 or 1 emits the identical dense list — a 1-expert
    MoE *is* an ffn as far as placement is concerned."""
    assert make_blocks(8, 3, 1) == make_blocks(8, 3)
    assert make_blocks(8, 3, 0) == make_blocks(8, 3)


@pytest.mark.parametrize("n_experts", [4, 8])
def test_uniform_experts_collapse_to_dense_delay(n_experts):
    """Uniform router load + co-located experts price the expert graph
    bit-for-bit equal to the dense ffn graph (power-of-two E makes the
    1/E load split binary-exact), under the full per-layer delay model."""
    net = layered_net(seed=3)
    dense_cost = layered_cost()
    moe_cost = layered_cost(n_experts=n_experts)
    dense_blocks = dense_cost.make_blocks()
    moe_blocks = moe_cost.make_blocks()

    rng = np.random.default_rng(0)
    col = rng.integers(0, net.n_devices, len(make_blocks(8)))
    dense_place = replicate_placement(col, dense_blocks)
    moe_place = replicate_placement(col, moe_blocks)  # experts -> ffn slot

    for tau in (1, 17):
        d = inference_delay(dense_place, dense_blocks, dense_cost, net, tau)
        m = inference_delay(moe_place, moe_blocks, moe_cost, net, tau)
        assert d == m
        dp = pipelined_inference_delay(dense_place, dense_blocks, dense_cost,
                                       net, tau, k=4)
        mp = pipelined_inference_delay(moe_place, moe_blocks, moe_cost,
                                       net, tau, k=4)
        assert dp == mp
        d_dev, d_link = resource_busy_times(dense_place, dense_blocks,
                                            dense_cost, net, tau)
        m_dev, m_link = resource_busy_times(moe_place, moe_blocks,
                                            moe_cost, net, tau)
        np.testing.assert_array_equal(d_dev, m_dev)
        assert d_link == m_link


# --------------------------------------- model-function invariance (unit)
def _tiny_moe():
    from repro.models.moe import expert_identity, init_moe
    from tests.conftest import reduced_config

    cfg = reduced_config("mixtral-8x7b")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    p["owner"], p["share"] = expert_identity(cfg.n_experts)
    return cfg, p


def _permute_moe(p, perm):
    idx = jnp.asarray(perm)
    out = dict(p)
    for n in ("w_gate", "w_up", "w_down"):
        out[n] = jnp.take(p[n], idx, axis=0)
    for n in ("owner", "share"):
        out[n] = jnp.take(p[n], idx, axis=-1)
    return out


def test_expert_permutation_preserves_logits_exactly():
    """A physical expert-row permutation with its owner/share maps leaves
    moe_block output BIT-identical: the one-hot combine gathers the same
    per-expert terms back into logical order before the gate reduction."""
    from repro.models.moe import moe_block
    from repro.models.partitioning import NULL

    cfg, p = _tiny_moe()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model),
                          jnp.float32)
    ref, _, freq = moe_block(cfg, p, x, NULL)
    rng = np.random.default_rng(7)
    for _ in range(3):
        perm = rng.permutation(cfg.n_experts)
        out, _, freq2 = moe_block(cfg, _permute_moe(p, perm), x, NULL)
        assert np.array_equal(np.asarray(ref), np.asarray(out))
        # the router-load signal is logical — invariant under re-layout
        assert np.array_equal(np.asarray(freq), np.asarray(freq2))


def test_expert_replication_preserves_logits_exactly():
    """Activating a replica splits the gate share exactly in half across
    the two physical copies of identical weights: 0.5·y + 0.5·y == y in
    binary fp, so the output is bit-identical."""
    from repro.models.moe import moe_block, replicate_expert
    from repro.models.partitioning import NULL

    cfg, p = _tiny_moe()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, cfg.d_model),
                          jnp.float32)
    ref, _, _ = moe_block(cfg, p, x, NULL)
    for e in range(cfg.n_experts):
        p2 = replicate_expert(p, e)
        assert p2["w_gate"].shape[0] == cfg.n_experts + 1
        out, _, _ = moe_block(cfg, p2, x, NULL)
        assert np.array_equal(np.asarray(ref), np.asarray(out))


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(seed=st.integers(0, 10_000), n_rep=st.integers(0, 2))
    @settings(max_examples=15, deadline=None)
    def test_migration_replication_compose_exactly(seed, n_rep):
        """Any composition of replications followed by a physical row
        permutation preserves moe_block output bit-for-bit — the invariant
        the serving engine relies on when it applies controller plans to
        the live weights."""
        from repro.models.moe import moe_block, replicate_expert
        from repro.models.partitioning import NULL

        cfg, p = _tiny_moe()
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(1, 4, cfg.d_model)), jnp.float32)
        ref, _, _ = moe_block(cfg, p, x, NULL)
        p2 = p
        for _ in range(n_rep):
            p2 = replicate_expert(p2, int(rng.integers(cfg.n_experts)))
        p2 = _permute_moe(p2, rng.permutation(p2["w_gate"].shape[0]))
        out, _, _ = moe_block(cfg, p2, x, NULL)
        assert np.array_equal(np.asarray(ref), np.asarray(out))
except ImportError:  # hypothesis is a dev-only dependency
    pass


# ------------------------------------------------- VLM supergroup perms
def test_apply_layer_head_perms_multidim_leading():
    """Satellite: ``perms`` with multiple leading index dims — (G, R, H)
    over a supergroup cache stack (G, R, B, T, H, dh) — permutes each
    leading cell independently (the per-layer VLM migration path)."""
    from repro.core.placement_bridge import apply_layer_head_perms

    G, R, B, T, H, dh = 2, 3, 2, 4, 4, 3
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(G, R, B, T, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(G, R, B, T, H, dh)), jnp.float32)
    perms = np.stack([[rng.permutation(H) for _ in range(R)]
                      for _ in range(G)])                      # (G, R, H)
    k2, v2 = apply_layer_head_perms(k, v, perms, layer_axis=0, head_axis=-2)
    assert k2.shape == k.shape
    for g in range(G):
        for r in range(R):
            np.testing.assert_array_equal(
                np.asarray(k2[g, r]), np.asarray(k[g, r][:, :, perms[g, r]]))
            np.testing.assert_array_equal(
                np.asarray(v2[g, r]), np.asarray(v[g, r][:, :, perms[g, r]]))


# --------------------------------------------- engine roundtrip (e2e)
def _tiny_mixtral_cfg():
    from repro.configs import get_config
    return get_config("mixtral-8x7b").with_overrides(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab_size=97, sliding_window=64,
        dtype="float32", param_dtype="float32")


def test_expert_migration_roundtrip_through_engine():
    """End-to-end: mixtral (reduced) streams through the continuous
    ServingEngine; a straggler on the expert-heavy device forces the
    controller to physically permute the expert weight rows mid-serve
    (no silent skip — the log reports applied expert migrations) and the
    generated streams equal a migration-free run bit-for-bit."""
    from repro.serving.engine import ServingEngine

    cfg = _tiny_mixtral_cfg()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def run(lam, straggle_at):
        eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=lam, seed=0,
                            net=DeviceNetwork.sample(2, seed=1))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                place = eng.controller.place
                counts = np.zeros(eng.net.n_devices)
                for bl in eng.controller.blocks:
                    if bl.kind == "expert":
                        counts[int(place[bl.index])] += 1
                eng.net.inject_straggler(int(counts.argmax()),
                                         slowdown=500.0)
            if not eng.step():
                break
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    with_mig, eng = run(3, straggle_at=4)
    without, _ = run(10 ** 9, None)
    assert with_mig == without and len(with_mig) == 5
    applied = [e for e in eng.migration_log
               if e["expert_applied"] and e["n_expert_migrations"]]
    assert applied, "expert migration silently skipped"
    assert all(e["expert_reason"] is None for e in applied)
    assert all(e["expert_mig_bytes"] > 0 for e in applied)
    # the weights were PHYSICALLY re-laid-out, owner maps moved with them
    owner = np.asarray(eng.params["layers"]["moe"]["owner"])
    assert not np.array_equal(owner,
                              np.tile(np.arange(cfg.n_experts), (2, 1)))
