"""Per-layer block graph: equivalence regressions vs the column lift,
solver guardrails, per-layer-beats-columns (exact), and per-layer head
permutation invariance through the serving engine's migration machinery."""
import itertools

import numpy as np
import pytest

from repro.core import (ALL_POLICIES, DeviceNetwork, graph_of, make_blocks,
                        replicate_placement)
from repro.core.blocks import CostModel, FFN, HEAD, PROJ
from repro.core.delay import (inference_delay, memory_feasible, memory_usage,
                              migration_delay)
from repro.core.network import GBPS
from repro.core.placement_bridge import (migration_pairs_layers,
                                         placement_to_perm,
                                         placement_to_perms, relative_perms)
from repro.core.solver import exact_myopic, exact_horizon

GB = 1024 ** 3


# ----------------------------------------------------------- graph basics
def test_make_blocks_layer_major_and_backcompat():
    single = make_blocks(4)
    assert [b.kind for b in single] == [HEAD] * 4 + [PROJ, FFN]
    assert all(b.layer == 0 for b in single)
    multi = make_blocks(4, 3)
    assert len(multi) == 3 * 6
    assert [b.index for b in multi] == list(range(18))
    assert [b.layer for b in multi] == sum([[l] * 6 for l in range(3)], [])
    # layer 0 of the multi-layer list is the single-layer list
    assert multi[:6] == single


def test_block_graph_edges():
    g = graph_of(make_blocks(4, 3))
    edges = g.edges
    assert len(edges) == 2 * 4          # (L-1) x heads
    for src, dst in edges:
        assert src.kind == FFN and dst.kind == HEAD
        assert dst.layer == src.layer + 1


def test_layer_mode_validation():
    with pytest.raises(ValueError):
        CostModel(d_model=512, n_heads=4, layer_mode="nope")


# --------------------------------------------- n_layers=1 bit-for-bit
@pytest.mark.parametrize("compute_mode", ["paper", "incremental"])
def test_single_layer_graph_reproduces_columns_bit_for_bit(compute_mode):
    """Acceptance: n_layers=1 per-layer graph == today's single-layer
    numbers exactly (same blocks, same arithmetic path)."""
    blocks = make_blocks(4)
    cost_c = CostModel(d_model=2048, n_heads=4, compute_mode=compute_mode)
    cost_g = CostModel(d_model=2048, n_heads=4, compute_mode=compute_mode,
                       layer_mode="graph")
    net = DeviceNetwork.sample(4, seed=3)
    rng = np.random.default_rng(0)
    p = rng.integers(0, 4, len(blocks))
    q = rng.integers(0, 4, len(blocks))
    for tau in (1, 7, 50):
        assert inference_delay(p, blocks, cost_c, net, tau) == \
            inference_delay(p, blocks, cost_g, net, tau)
        assert migration_delay(p, q, blocks, cost_c, net, tau) == \
            migration_delay(p, q, blocks, cost_g, net, tau)
        np.testing.assert_array_equal(
            memory_usage(p, blocks, cost_c, net, tau),
            memory_usage(p, blocks, cost_g, net, tau))


# ------------------------------------- column-replicated equivalence
@pytest.mark.parametrize("compute_mode", ["paper", "incremental"])
def test_column_replicated_graph_matches_scaled_columns(compute_mode):
    """Equivalence regression: a uniform per-layer graph with a
    column-replicated placement must match the n_layers-scaled single-layer
    CostModel on inference delay, migration delay, and memory.

    Memory and migration match on ANY network (per-layer blocks each carry
    their single-layer footprint; a column move is n_layers identical
    moves).  Inference delay additionally requires the terms the column
    model cannot see to vanish: the controller row is uniform (so the one
    w_in charge factors out of the per-head max identically) and every
    link touching the proj/ffn devices is infinite (the column lift never
    prices inter-layer hops or proj->ffn transfers — with those free, the
    remaining head-compute, head->proj serialization, and proj/ffn compute
    terms must agree exactly)."""
    L, H, V = 4, 4, 4
    cost_c = CostModel(d_model=512, n_heads=H, n_layers=L,
                       compute_mode=compute_mode)
    cost_g = CostModel(d_model=512, n_heads=H, n_layers=L,
                       compute_mode=compute_mode, layer_mode="graph")
    bl_c = make_blocks(H)
    bl_g = make_blocks(H, L)
    col = np.array([0, 1, 2, 3, 1, 2])     # heads spread, proj=1, ffn=2
    pg = replicate_placement(col, bl_g)

    net = DeviceNetwork.sample(V, seed=3)
    net.bandwidth[net.controller, :] = 5e8
    for dev in (1, 2):                     # proj and ffn devices
        net.bandwidth[dev, :] = np.inf
        net.bandwidth[:, dev] = np.inf
    np.fill_diagonal(net.bandwidth, np.inf)
    for tau in (1, 9, 40):
        a = inference_delay(col, bl_c, cost_c, net, tau)
        b = inference_delay(pg, bl_g, cost_g, net, tau)
        assert np.isclose(a, b, rtol=1e-12), (tau, a, b)

    # migration + memory: fully heterogeneous network, no special links
    net2 = DeviceNetwork.sample(V, seed=11)
    col2 = np.array([1, 0, 3, 2, 2, 0])
    pg2 = replicate_placement(col2, bl_g)
    for tau in (2, 17):
        ma = migration_delay(col, col2, bl_c, cost_c, net2, tau)
        mb = migration_delay(pg, pg2, bl_g, cost_g, net2, tau)
        assert np.isclose(ma, mb, rtol=1e-12)
        np.testing.assert_allclose(
            memory_usage(col2, bl_c, cost_c, net2, tau),
            memory_usage(pg2, bl_g, cost_g, net2, tau), rtol=1e-12)


# ----------------------------------------------------- solver guardrail
def test_exact_solvers_refuse_unenumerable_graphs():
    """A per-layer graph above the enumerable size must raise a clear
    ValueError immediately, not hang combinatorially."""
    blocks = make_blocks(8, 8)                      # 80 blocks
    cost = CostModel(d_model=512, n_heads=8, n_layers=8, layer_mode="graph")
    net = DeviceNetwork.sample(5, seed=0)
    with pytest.raises(ValueError, match="enumerable"):
        exact_myopic(blocks, cost, net, 1, None)
    # horizon cap is tighter: 9^6 placements pass myopic but not the DP
    blocks6 = make_blocks(4)
    cost6 = CostModel(d_model=512, n_heads=4)
    nets = [DeviceNetwork.sample(9, seed=0) for _ in range(2)]
    exact_myopic(blocks6, cost6, nets[0], 1, None)  # allowed (531441 <= 1e6)
    with pytest.raises(ValueError, match="enumerable"):
        exact_horizon(blocks6, cost6, nets)


# ------------------------------------- per-layer beats columns (exact)
def test_per_layer_optimum_strictly_beats_column_optimum():
    """The structural claim behind the layered benchmark: on a
    heterogeneous-bandwidth network the per-layer optimum is strictly below
    the best column-co-partitioned placement (the column space is a strict
    subset of the per-layer space)."""
    L, H, V = 2, 2, 3
    blocks = make_blocks(H, L)
    cost = CostModel(d_model=512, n_heads=H, n_layers=L,
                     compute_mode="paper", layer_mode="graph")
    net = DeviceNetwork.sample(V, seed=0, bw_range=(0.02 * GBPS, 2 * GBPS),
                               compute_range=(5e9, 50e9))
    p_star, v_star = exact_myopic(blocks, cost, net, 3, None)
    assert p_star is not None
    from repro.core.delay import total_delay
    best_col = min(
        total_delay(None, replicate_placement(np.array(c), blocks), blocks,
                    cost, net, 3)
        for c in itertools.product(range(V), repeat=H + 2)
        if memory_feasible(replicate_placement(np.array(c), blocks),
                           blocks, cost, net, 3))
    assert v_star < best_col - 1e-12
    # and the replicated best-column IS reachable by the graph solver
    assert v_star <= best_col


def test_column_copartition_policy_is_column_replicated():
    blocks = make_blocks(4, 3)
    cost = CostModel(d_model=2048, n_heads=4, n_layers=3,
                     compute_mode="incremental", layer_mode="graph")
    net = DeviceNetwork.sample(4, seed=2)
    pol = ALL_POLICIES["column-copartition"](blocks, cost, deadline=0.5)
    p = pol.place(net, 1, None)
    mat = p.reshape(3, 6)
    for row in mat[1:]:
        np.testing.assert_array_equal(row, mat[0])


# -------------------------------------------------- per-layer bridge
def test_placement_to_perms_per_layer():
    blocks = make_blocks(8, 2)
    rng = np.random.default_rng(4)
    place = rng.integers(0, 4, len(blocks))
    perms = placement_to_perms(place, blocks, n_slots=4, heads_per_slot=2)
    assert perms.shape == (2, 8)
    for l in range(2):
        assert sorted(perms[l].tolist()) == list(range(8))
    # layer rows equal the single-layer mapping of that layer's blocks
    g = graph_of(blocks)
    for l in range(2):
        ref = placement_to_perm(place, g.layer_blocks(l), 4, 2)
        np.testing.assert_array_equal(perms[l], ref)
    assert migration_pairs_layers(perms, perms, 2) == []
    # a head moving devices in layer 1 only shows up as a layer-1 pair
    place2 = place.copy()
    h = g.heads[1][0]
    place2[h.index] = (place2[h.index] + 1) % 4
    perms2 = placement_to_perms(place2, blocks, 4, 2)
    pairs = migration_pairs_layers(perms, perms2, 2)
    assert pairs and all(p[0] == 1 for p in pairs)


def test_relative_perms_roundtrip():
    rng = np.random.default_rng(0)
    prev = np.stack([rng.permutation(6) for _ in range(3)])
    new = np.stack([rng.permutation(6) for _ in range(3)])
    rel = relative_perms(prev, new)
    for l in range(3):
        np.testing.assert_array_equal(prev[l][rel[l]], new[l])


# ------------------------- migration invariance through the engine
def test_per_layer_head_perms_are_function_invariant_in_engine():
    """Per-layer head permutations applied to weights AND cache (the
    serving engine's physical migration) leave the next decode step's
    logits bit-identical — even when every layer gets a DIFFERENT
    permutation, which the old single-permutation bridge could not
    express."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from tests.conftest import reduced_config
    from repro.core.placement_bridge import (apply_layer_head_perms,
                                             permute_model_heads_layers)
    from repro.serving.engine import ServingEngine

    cfg = reduced_config("musicgen-large")      # MHA: physical path
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    assert eng.cost.layer_mode == "graph"
    assert eng.controller.n_layers == cfg.n_layers
    rng = np.random.default_rng(0)
    for n in (5, 9):
        eng.submit(rng.integers(0, 97, size=n), max_new_tokens=4)
    eng._admit()
    for _ in range(2):                          # populate per-slot caches
        eng.step()
    ref_logits, _ = eng.model.decode_step(eng.params, eng.state,
                                          jnp.asarray(eng._next))

    H = eng.state["cache"]["k"].shape[-2]
    perms = np.stack([rng.permutation(H) for _ in range(cfg.n_layers)])
    assert any(not np.array_equal(perms[l], perms[0])
               for l in range(cfg.n_layers))    # genuinely per-layer
    params2 = permute_model_heads_layers(eng.params, perms)
    k2, v2 = apply_layer_head_perms(eng.state["cache"]["k"],
                                    eng.state["cache"]["v"], perms,
                                    layer_axis=0, head_axis=-2)
    state2 = dict(eng.state, cache=dict(eng.state["cache"], k=k2, v=v2))
    out_logits, _ = eng.model.decode_step(params2, state2,
                                          jnp.asarray(eng._next))
    np.testing.assert_allclose(np.asarray(ref_logits),
                               np.asarray(out_logits), atol=1e-5, rtol=1e-5)


def test_controller_emits_per_layer_plans_and_cache_roundtrip():
    """Graph-mode controller plans carry one permutation per layer;
    applying a plan to a stacked cache and then the inverse plan restores
    it."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.controller import ControllerConfig, IntervalController

    H, L, V = 8, 3, 4
    cost = CostModel(d_model=512, n_heads=H, n_layers=L,
                     compute_mode="incremental", layer_mode="graph")
    net = DeviceNetwork.sample(V, seed=1)
    ctl = IntervalController(H, cost, net,
                             ControllerConfig(lam=4, heads_per_slot=2))
    plan1 = ctl.step_interval()
    assert plan1["perms"].shape == (L, V * 2)
    net.inject_straggler(int(ctl.head_counts().argmax()), slowdown=100.0)
    ctl.observe(compute_avail=net.compute_avail)
    plan2 = ctl.step_interval()
    assert plan2["perms"].shape == (L, V * 2)
    cache = jnp.arange(L * 2 * 5 * 8 * 4, dtype=jnp.float32
                       ).reshape(L, 2, 5, 8, 4)
    k2, v2 = ctl.apply_to_cache(cache, cache, plan2)
    if plan2["migrations"]:
        assert not np.array_equal(np.asarray(k2), np.asarray(cache))
    # inverse plan restores the original layout
    inv = {"perms": plan2["prev_perms"], "prev_perms": plan2["perms"],
           "migrations": plan2["migrations"]}
    k3, _ = ctl.apply_to_cache(k2, v2, inv)
    np.testing.assert_array_equal(np.asarray(k3), np.asarray(cache))
