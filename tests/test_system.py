"""End-to-end system tests: checkpointing (atomic/restart/elastic), data
pipeline determinism, serving engine (+ migration invariance under an
injected straggler), optimizer behaviour, placement bridge."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.blocks import make_blocks
from repro.core.placement_bridge import (migration_pairs, permute_model_heads,
                                         placement_to_perm)
from repro.data.pipeline import SyntheticLM, make_train_pipeline
from repro.models.api import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.elastic import best_mesh_shape
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serving.engine import ServingEngine
from tests.conftest import reduced_config


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path, rng_key):
    cfg = reduced_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(rng_key)
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(3, params)
    ck.save(7, params)
    assert ck.all_steps() == [3, 7]
    restored = ck.restore(7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc(tmp_path, rng_key):
    cfg = reduced_config("musicgen-large")
    params = build_model(cfg).init(rng_key)
    ck = Checkpointer(tmp_path, keep=1)
    for s in (1, 2, 3):
        ck.save(s, params)
    assert ck.all_steps() == [3]          # gc keeps 1
    # a partial (uncommitted) dir must be invisible
    bad = tmp_path / "step_00000099"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 3


def test_checkpoint_detects_corruption(tmp_path, rng_key):
    cfg = reduced_config("llama3-8b")
    params = build_model(cfg).init(rng_key)
    ck = Checkpointer(tmp_path)
    path = ck.save(1, params)
    victim = next(p for p in path.glob("*.npy"))
    arr = np.asarray(np.load(victim)).copy()
    arr.flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError):
        ck.restore(1, params)


def test_training_restart_is_bit_identical(tmp_path, rng_key):
    """Kill-and-resume: restored run == uninterrupted run (data cursor +
    params + opt state all restored)."""
    cfg = reduced_config("llama3-8b")
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    src = SyntheticLM(cfg.vocab_size, 16, 4, seed=5)
    it = iter(src)
    params = model.init(rng_key)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        p, o = opt.update(grads, opt_state, params)
        return p, o, loss

    ck = Checkpointer(tmp_path)
    for i in range(2):
        params, opt_state, _ = step(params, opt_state,
                                    {k: jnp.asarray(v) for k, v in
                                     next(it).items()})
    ck.save(2, {"params": params, "opt": opt_state,
                "data": src.state_dict()})
    for i in range(2):
        params, opt_state, loss_a = step(params, opt_state,
                                         {k: jnp.asarray(v) for k, v in
                                          next(it).items()})
    # restart from the checkpoint with a fresh data source
    src2 = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    state = ck.restore(2, {"params": params, "opt": opt_state,
                           "data": src.state_dict()})
    src2.load_state_dict(state["data"])
    it2 = iter(src2)
    p2, o2 = state["params"], state["opt"]
    for i in range(2):
        p2, o2, loss_b = step(p2, o2, {k: jnp.asarray(v) for k, v in
                                       next(it2).items()})
    assert float(loss_a) == float(loss_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------- data
def test_pipeline_determinism_and_labels():
    src = SyntheticLM(97, 8, 2, seed=1)
    a = next(iter(src))
    b = next(iter(SyntheticLM(97, 8, 2, seed=1)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    full = np.concatenate([a["tokens"], a["labels"][:, -1:]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], a["labels"])
    assert a["tokens"].max() < 97 and a["tokens"].min() >= 0


def test_prefetcher_yields_batches():
    cfg = reduced_config("llama3-8b")
    shape = type("S", (), {"seq_len": 8, "global_batch": 2})()
    src, it = make_train_pipeline(cfg, shape, None)
    b = next(it)
    assert b["tokens"].shape == (2, 8)
    it.close()


# --------------------------------------------------------------- optimizer
def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip_and_schedule():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) < float(sched(jnp.asarray(10)))
    assert float(sched(jnp.asarray(100))) < float(sched(jnp.asarray(10)))
    opt = AdamW(lr=1e-2, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    p1, _ = opt.update({"w": jnp.full(3, 1e9)}, state, params)
    assert float(jnp.abs(p1["w"]).max()) < 1.0  # clipped update stays sane


# --------------------------------------------------------- fault tolerance
def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(4, straggler_factor=1.5)
    for _ in range(8):
        for j in range(4):
            mon.record_step(j, 0.1 if j != 2 else 0.4)
    assert mon.stragglers() == [2]
    avail = mon.availability(100.0)
    assert avail[2] < 30.0 and avail[0] > 90.0


def test_best_mesh_shape_elastic():
    assert best_mesh_shape(256) == (16, 16)
    assert best_mesh_shape(255) == (255, 1)   # odd survivor count: DP-only
    assert best_mesh_shape(240) == (15, 16)
    assert best_mesh_shape(7) == (7, 1)
    assert best_mesh_shape(24) == (3, 8)


# ----------------------------------------------------------- placement map
def test_placement_perm_roundtrip():
    blocks = make_blocks(8)
    place = np.array([3, 3, 1, 1, 0, 0, 2, 2, 0, 0])  # 8 heads + proj + ffn
    perm = placement_to_perm(place, blocks, n_slots=4, heads_per_slot=2)
    assert sorted(perm.tolist()) == list(range(8))
    assert set(perm[6:8]) == {0, 1}   # device 3's heads -> slot 3
    assert set(perm[0:2]) == {4, 5}   # device 0's heads -> slot 0
    assert migration_pairs(perm, perm, 2) == []


def test_permute_model_heads_is_function_invariant(rng_key):
    cfg = reduced_config("musicgen-large")  # MHA: KvE == Hp
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = jax.random.randint(rng_key, (2, 12), 0, cfg.vocab_size)
    base, _ = model.forward(params, toks)
    p2 = permute_model_heads(params, np.array([2, 0, 3, 1]))
    out, _ = model.forward(p2, toks)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------- serving
def test_engine_serves_and_migration_preserves_tokens():
    cfg = reduced_config("musicgen-large")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=8) for _ in range(4)]

    def run(lam, straggle=False):
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0)
        if straggle:
            eng.net.inject_straggler(0, slowdown=50.0)
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        done = eng.run()
        return [r.out_tokens for r in sorted(done, key=lambda r: r.rid)]

    with_ctrl = run(lam=4, straggle=True)
    without = run(lam=10 ** 9)
    assert with_ctrl == without  # migrations never change the function
    assert len(with_ctrl) == 4
