"""Unit tests for the optimized-HLO text parser (launch/hlo_analysis).

All on small committed HLO fixtures (tests/fixtures/hlo/) — no jax, no
compiles: these pin the parsing semantics the hot-path auditor
(repro.analysis.hlo_audit) and the roofline benches both depend on.
"""
import pathlib

from repro.launch import hlo_analysis as H

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "hlo"


def _read(name: str) -> str:
    return (FIXTURES / name).read_text()


# --------------------------------------------------------------- while trips
class TestWhileTripInference:
    def test_trip_count_from_condition_constant(self):
        comps = H._split_computations(_read("while_collectives.hlo"))
        # cond compares the counter against constant(5)
        assert H._trip_count(comps["cond.1"]) == 5

    def test_trip_count_falls_back_to_one(self):
        assert H._trip_count("no comparison constants here") == 1
        # absurd constants (type-id noise) are not trip counts
        assert H._trip_count("%c = s32[] constant(9999999)") == 1

    def test_loop_body_collectives_are_trip_multiplied(self):
        d = H.collective_bytes(_read("while_collectives.hlo"))
        # body all-reduce: f32[128] = 512 B, x5 trips
        assert d["all-reduce"] == 5 * 512.0
        assert d["_counts"]["all-reduce"] == 5
        # entry-level all-gather counted once: result f32[128] = 512 B
        assert d["all-gather"] == 512.0
        assert d["_counts"]["all-gather"] == 1


# -------------------------------------------------------------- async pairs
class TestAsyncCollectivePairs:
    def test_start_done_pair_counted_once(self):
        d = H.collective_bytes(_read("async_pair.hlo"))
        # counted at -start (tuple result: f32[16] + f32[128] = 576 B);
        # the -done must NOT double-count
        assert d["_counts"]["all-gather"] == 1
        assert d["all-gather"] == 576.0


# ------------------------------------------------------- nested-call memoing
class TestNestedCallMemoization:
    def test_shared_callee_counted_per_call_site(self):
        # entry -> mid_a -> leaf and entry -> mid_b -> leaf: the leaf's
        # all-reduce (f32[64] = 256 B) is memoized once but billed at both
        # call sites
        d = H.collective_bytes(_read("nested_call.hlo"))
        assert d["all-reduce"] == 2 * 256.0
        assert d["_counts"]["all-reduce"] == 2

    def test_reduction_to_apply_is_not_billed_as_call(self):
        # the all-reduce's own to_apply=%scalar_add must not add bytes
        d = H.collective_bytes(_read("nested_call.hlo"))
        total = sum(v for k, v in d.items() if not k.startswith("_"))
        assert total == 2 * 256.0


# ------------------------------------------------------- layout-only fusion
class TestLayoutOnlyFusionExclusion:
    def test_layout_only_fusion_excluded_from_hbm(self):
        full = H.full_analysis(_read("layout_fusion.hlo"))
        # dot: out 64x64, k=64 -> 2*4096*64 flops; hbm = lhs+rhs+out f32
        assert full["dot_flops"] == 2 * 64 * 64 * 64
        assert full["hbm_bytes"] == 3 * 64 * 64 * 4

    def test_compute_fusion_is_counted(self):
        # same module, but the fused computation does real math: the
        # fusion's operand+result traffic must now be billed
        txt = _read("layout_fusion.hlo").replace("convert(", "exponential(")
        full = H.full_analysis(txt)
        fusion_bytes = 64 * 64 * 2 + 64 * 64 * 4  # bf16 in, f32 out
        assert full["hbm_bytes"] == 3 * 64 * 64 * 4 + fusion_bytes
        assert full["dot_flops"] == 2 * 64 * 64 * 64


# ------------------------------------------------- donation introspection
CACHE_BYTES = 2 * 2 * 64 * 4 * 16 * 2  # bf16[2,2,64,4,16]


class TestDonationIntrospection:
    def test_input_output_aliases_parsed(self):
        aliases = H.input_output_aliases(_read("donation_ok.hlo"))
        assert aliases == {(1,): 1}
        assert H.input_output_aliases(_read("donation_failure.hlo")) == {}

    def test_entry_output_shapes(self):
        outs = H.entry_output_shapes(_read("donation_failure.hlo"))
        assert outs == [("f32", "2,256", 2 * 256 * 4),
                        ("bf16", "2,2,64,4,16", CACHE_BYTES)]

    def test_find_copy_ops_chases_to_parameter(self):
        copies = H.find_copy_ops(_read("donation_failure.hlo"),
                                 min_bytes=CACHE_BYTES)
        assert len(copies) == 1
        c = copies[0]
        assert c["bytes"] == CACHE_BYTES
        assert c["operand"] == "Arg_1.2"
        assert c["from_parameter"] is True

    def test_min_bytes_filters_small_copies(self):
        assert H.find_copy_ops(_read("donation_failure.hlo"),
                               min_bytes=CACHE_BYTES + 1) == []

    def test_in_place_update_module_has_no_param_copies(self):
        assert H.find_copy_ops(_read("donation_ok.hlo"),
                               min_bytes=CACHE_BYTES) == []
