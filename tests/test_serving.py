"""Continuous-batching serving engine: scheduler, slot correctness,
migration-under-staggered-occupancy, bounded prefill compiles, sampler
key discipline."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.serving.engine import (ServingEngine, WaveServingEngine,
                                  default_buckets, make_engine)
from tests.conftest import reduced_config


def _reference_tokens(model, params, prompt, n_tokens, max_seq):
    """Greedy decode of one request alone, unpadded — the ground truth the
    batched scheduler must reproduce per slot."""
    state = model.init_decode_state(params, 1, max_seq)
    logits, state = model.prefill(params, state,
                                  jnp.asarray(prompt[None], jnp.int32))
    toks = [int(jnp.argmax(logits[0]))]
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    for _ in range(n_tokens - 1):
        logits, state = step(params, state,
                             jnp.asarray([toks[-1]], jnp.int32))
        # rpr: ignore[RPR004] -- reference decoder: greedy stream must be
        # read back per step to feed the next token
        toks.append(int(jnp.argmax(logits[0])))
    return toks


# ------------------------------------------------------- mixed-length batch
def test_mixed_prompt_lengths_one_batch_match_reference():
    """Requests with different prompt lengths are admitted into ONE batch
    (no equal-length wave restriction) and each slot's greedy stream equals
    the single-request reference."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 9, 13)]
    eng = ServingEngine(cfg, n_slots=3, max_seq=48, lam=10 ** 9, seed=0)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    done = eng.run()
    assert len(done) == 3
    # all three lengths were resident simultaneously (admitted pre-decode)
    assert [a["step"] for a in list(eng.admission_log)[:3]] == [0, 0, 0]
    for r in sorted(done, key=lambda r: r.rid):
        ref = _reference_tokens(eng.model, eng.params, prompts[r.rid],
                                6, 48)
        assert r.out_tokens == ref, f"rid {r.rid}"


# ------------------------------------------------------------- slot reuse
def test_freed_slot_refilled_before_batch_drains():
    """A slot whose request finishes is re-admitted into while the other
    slot is still mid-decode — the defining property of continuous
    batching (acceptance criterion)."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=10 ** 9, seed=0)
    eng.submit(rng.integers(0, 97, size=5), max_new_tokens=3)    # short
    eng.submit(rng.integers(0, 97, size=7), max_new_tokens=20)   # long
    eng.submit(rng.integers(0, 97, size=6), max_new_tokens=3)    # refill
    done = eng.run()
    assert len(done) == 3
    refill = next(a for a in eng.admission_log if a["rid"] == 2)
    long_req = next(r for r in done if r.rid == 1)
    # rid 2 entered while rid 1 was still generating: after decode started,
    # before the long request's last token
    assert 0 < refill["step"] < eng.decode_steps
    assert len(long_req.out_tokens) == 20
    # and it reused a freed slot, not a third one
    assert refill["slot"] in (0, 1)
    # utilization bookkeeping saw overlapping occupancy
    assert eng.slot_busy_steps > max(len(r.out_tokens) for r in done)


# ---------------------------------------------- migration @ unequal depth
def test_migration_invariance_with_staggered_slots():
    """Head migrations permute weights+cache while slots sit at different
    sequence positions (staggered admissions): the generated streams must
    be identical to a migration-free run — §III.D's loop on a live
    continuous batch."""
    cfg = reduced_config("musicgen-large")   # MHA: physical migration path
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def run(lam, straggle):
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0)
        if straggle:
            eng.net.inject_straggler(0, slowdown=50.0)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=6 + 3 * (i % 2))
        done = eng.run()
        return {r.rid: r.out_tokens for r in done}, eng

    with_ctrl, eng = run(lam=3, straggle=True)
    without, _ = run(lam=10 ** 9, straggle=False)
    assert with_ctrl == without
    assert len(with_ctrl) == 5
    assert len(eng.migration_log) >= 2          # controller actually ran
    # staggered: at least one admission happened mid-stream
    assert any(a["step"] > 0 for a in eng.admission_log)


# ------------------------------------------------------ bounded recompiles
def test_prefill_compiles_bounded_by_buckets():
    """10 distinct prompt lengths must share a handful of bucketed prefill
    shapes — recompiles are O(len(buckets)), not O(#lengths)."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(1)
    lengths = list(range(3, 23, 2))             # 10 distinct lengths
    eng = ServingEngine(cfg, n_slots=4, max_seq=64, lam=10 ** 9, seed=0)
    for n in lengths:
        eng.submit(rng.integers(0, 97, size=n), max_new_tokens=2)
    done = eng.run()
    assert len(done) == len(lengths)
    assert eng.prefill_buckets_used <= set(eng.buckets)
    assert len(eng.prefill_buckets_used) <= 3 < len(set(lengths))


def test_default_buckets_cover_max_seq():
    bks = default_buckets(48)
    assert bks[-1] == 48 and all(b <= 48 for b in bks)
    eng_bks = default_buckets(512)
    assert eng_bks == [8, 16, 32, 64, 128, 256, 512]


# ------------------------------------------------------------- sampler keys
def test_consecutive_nongreedy_samples_use_distinct_keys():
    """Seed bug: the post-prefill sample and the first post-decode sample
    shared PRNGKey(decode_steps). Every _sample call now folds a fresh
    counter into the base key."""
    cfg = reduced_config("llama3-8b")
    rng = np.random.default_rng(2)
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0,
                        greedy=False)
    for n in (5, 8):
        eng.submit(rng.integers(0, 97, size=n), max_new_tokens=4)
    eng.run()
    # 2 prefill samples + >=3 decode samples, all distinct
    assert len(eng.sample_key_log) >= 5
    assert len(set(eng.sample_key_log)) == len(eng.sample_key_log)
    # wave engine shares the fixed sampler
    weng = WaveServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9,
                             seed=0, greedy=False)
    weng.submit(rng.integers(0, 97, size=5), max_new_tokens=4)
    weng.run()
    assert len(set(weng.sample_key_log)) == len(weng.sample_key_log) >= 4


# ------------------------------------------------------------ engine picker
def test_make_engine_falls_back_for_unsupported_archs():
    moe = reduced_config("mixtral-8x7b")        # sliding_window -> ring cache
    assert moe.sliding_window
    eng = make_engine(moe, n_slots=2, max_seq=32, lam=10 ** 9, seed=0)
    assert isinstance(eng, WaveServingEngine)
    with pytest.raises(NotImplementedError):
        ServingEngine(moe, n_slots=2, max_seq=32, lam=10 ** 9, seed=0)
    # the reject is cfg-only (no params built), typed, and covers every
    # family without a slot API; VLM is slot-wired now (test_pipelined)
    for arch in ("rwkv6-7b", "zamba2-2.7b"):
        cfg = reduced_config(arch)
        with pytest.raises(NotImplementedError):
            ServingEngine(cfg, n_slots=2, max_seq=32, seed=0)
    dense = reduced_config("llama3-8b")
    assert isinstance(make_engine(dense, n_slots=2, max_seq=32, seed=0),
                      ServingEngine)
