"""Async serving runtime: bit-identity with the synchronous engine,
typed backpressure, clean drain, and worker-hang detection through the
fault-tolerance heartbeat monitor."""
import asyncio

import numpy as np
import pytest

from repro.serving.async_runtime import (AsyncServingEngine,
                                         QueueFullError)
from repro.serving.engine import ServingEngine, WaveServingEngine
from repro.serving.workload import (VirtualClock, drive_virtual,
                                    make_workload)
from tests.conftest import reduced_config


def _cfg():
    return reduced_config("llama3-8b")


def _engine(cfg, **kw):
    return ServingEngine(cfg, n_slots=2, max_seq=64, lam=10 ** 9,
                         seed=0, **kw)


def _workload(cfg, rate=0.3, horizon=30.0, seed=5):
    return make_workload("poisson", rate=rate, horizon=horizon, seed=seed,
                         vocab=cfg.vocab_size)


async def _run_async(eng, reqs, **rt_kw):
    rt = AsyncServingEngine(eng, queue_limit=len(reqs) + 1, **rt_kw)
    async with rt:
        handles = [rt.submit(r.prompt, max_new_tokens=r.max_new_tokens)
                   for r in sorted(reqs, key=lambda r: r.t_arrival)]
        await rt.drain()
    return handles


# ------------------------------------------------------------ bit-identity
@pytest.mark.parametrize("kw", [{}, {"paged": True, "page_size": 8}],
                         ids=["dense", "paged"])
def test_async_streams_bit_identical_to_sync(kw):
    """The tentpole contract: same admission order => every per-request
    token stream equals the synchronous engine's, dense and paged."""
    cfg = _cfg()
    reqs = _workload(cfg)
    sync = drive_virtual(_engine(cfg, **kw), reqs)
    assert sync["n_finished"] == len(reqs)
    handles = asyncio.run(_run_async(_engine(cfg, **kw), reqs))
    assert {h.rid: h.tokens for h in handles} == sync["streams"]
    for h in handles:
        assert h.error is None
        assert h.t_first is not None and h.t_done >= h.t_first


def test_stream_iteration_matches_result():
    """The async-generator view and the awaited result view agree."""
    cfg = _cfg()
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size

    async def go():
        async with AsyncServingEngine(_engine(cfg)) as rt:
            h = rt.submit(prompt, max_new_tokens=6)
            seen = [tok async for tok in h.stream()]
            return seen, await h.result()

    seen, result = asyncio.run(go())
    assert seen == result and len(seen) == 6


# ------------------------------------------------------------ backpressure
def test_queue_full_is_typed_reject():
    cfg = _cfg()
    eng = _engine(cfg)
    rt = AsyncServingEngine(eng, queue_limit=2)
    p = np.arange(4, dtype=np.int32)
    rt.submit(p), rt.submit(p)
    assert rt.queue_depth == 2
    with pytest.raises(QueueFullError, match="admission queue full"):
        rt.submit(p)
    # nothing was enqueued by the rejected call
    assert rt.queue_depth == 2
    assert len(eng.queue) == 0          # runtime never started


def test_submit_after_drain_rejected():
    cfg = _cfg()

    async def go():
        rt = AsyncServingEngine(_engine(cfg))
        async with rt:
            rt.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)
            await rt.drain()
            with pytest.raises(RuntimeError, match="draining"):
                rt.submit(np.arange(4, dtype=np.int32))

    asyncio.run(go())


def test_oversized_prompt_fails_its_own_handle():
    """An intake reject (prompt longer than the biggest bucket) surfaces
    on THAT request's stream; the runtime and other requests live on."""
    cfg = _cfg()

    async def go():
        async with AsyncServingEngine(_engine(cfg)) as rt:
            ok = rt.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
            bad = rt.submit(np.zeros(500, np.int32), max_new_tokens=3)
            with pytest.raises(ValueError):
                await bad.result()
            return await ok.result()

    assert len(asyncio.run(go())) == 3


# -------------------------------------------------------------------- drain
def test_drain_leaves_no_live_pages():
    cfg = _cfg()
    reqs = _workload(cfg, rate=0.5)
    eng = _engine(cfg, paged=True, page_size=8)
    handles = asyncio.run(_run_async(eng, reqs))
    assert all(h._finished.is_set() for h in handles)
    assert len(eng.queue) == 0 and not eng._active()
    for a in eng.allocators:
        a.check_invariants()
        assert a.live_pages == 0 and a.reserved_pages == 0


def test_runtime_requires_slot_engine_and_free_sink():
    cfg = _cfg()
    weng = WaveServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9,
                             seed=0)
    with pytest.raises(TypeError, match="ServingEngine"):
        AsyncServingEngine(weng)
    eng = _engine(cfg)
    eng.token_sink = lambda r, t, d: None
    with pytest.raises(ValueError, match="token_sink"):
        AsyncServingEngine(eng)


# ----------------------------------------------------------- hang detection
def test_hung_worker_detected_and_logged_once():
    """The formerly-orphaned HeartbeatMonitor now guards the serving
    path: a worker silent past the timeout is flagged exactly once,
    logged into the monitor's event log, and revives on heartbeat."""
    clk = VirtualClock()
    rt = AsyncServingEngine(_engine(_cfg()), heartbeat_timeout=5.0,
                            heartbeat_clock=clk.now)
    assert rt.check_workers() == []
    clk.advance(6.0)
    assert sorted(rt.check_workers()) == [rt.ADMISSION, rt.DECODE]
    assert rt.check_workers() == []          # one-shot, not per-poll
    hung = [e for e in rt.monitor.events if e["kind"] == "worker_hung"]
    assert len(hung) == 2
    assert all(e["silent_s"] > 5.0 for e in hung)
    # a late heartbeat revives the worker; going silent again re-flags it
    rt.monitor.record_heartbeat(rt.DECODE)
    clk.advance(6.0)
    assert rt.check_workers() == [rt.DECODE]


def test_live_workers_heartbeat_under_load():
    """After a real drain the workers have been heartbeating: nobody is
    flagged hung and the decode worker accumulated step telemetry."""
    cfg = _cfg()
    reqs = _workload(cfg)
    eng = _engine(cfg)
    rt_holder = {}

    async def go():
        rt = AsyncServingEngine(eng, queue_limit=len(reqs) + 1)
        rt_holder["rt"] = rt
        async with rt:
            for r in sorted(reqs, key=lambda r: r.t_arrival):
                rt.submit(r.prompt, max_new_tokens=r.max_new_tokens)
            await rt.drain()

    asyncio.run(go())
    rt = rt_holder["rt"]
    assert rt.check_workers() == []
    assert len(rt.monitor.slots[rt.DECODE].step_times) > 0


# ------------------------------------------------------- load observability
def test_interval_log_carries_arrival_rate_and_queue_depth():
    """The controller's interval records now include the engine's
    observed load — the signal the traffic-adaptive search will use."""
    cfg = _cfg()
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=8, seed=0)
    reqs = _workload(cfg, rate=0.4, horizon=25.0, seed=3)
    drive_virtual(eng, reqs)
    assert eng.migration_log, "lam=8 must tick at least one interval"
    for entry in eng.migration_log:
        assert entry["arrival_rate"] is not None
        assert entry["arrival_rate"] >= 0.0
        assert entry["queue_depth"] is not None
    hist = eng.controller.history
    assert hist and all("arrival_rate" in h and "queue_depth" in h
                        for h in hist)
    # arrivals per step summed over intervals ~ total submissions
    assert sum(h["arrival_rate"] for h in hist) > 0.0
