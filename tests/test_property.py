"""Property-based tests (hypothesis) on system invariants.

Skipped cleanly when hypothesis is absent (it is a dev-only dependency:
``pip install -r requirements-dev.txt``)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DeviceNetwork, inference_delay, memory_usage,
                        migration_delay)
from repro.core.algorithm import ResourceAwareAssigner
from repro.core.blocks import CostModel, make_blocks
from repro.core.placement_bridge import migration_pairs, placement_to_perm
from repro.launch.hlo_analysis import _shape_bytes, collective_bytes
from repro.models import layers as L
from repro.models.partitioning import NULL

SETTINGS = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------- cost model
@given(tau=st.integers(1, 5000), d_model=st.sampled_from([512, 2048, 4096]),
       h=st.sampled_from([4, 8, 32]), b=st.sampled_from([1, 2, 4]))
@settings(**SETTINGS)
def test_costs_positive_and_monotone(tau, d_model, h, b):
    cost = CostModel(d_model=d_model, n_heads=h, bytes_per_param=b)
    for bl in make_blocks(h):
        assert cost.memory(bl, tau) > 0
        assert cost.compute(bl, tau) > 0
        assert cost.memory(bl, tau + 1) > cost.memory(bl, tau)


@given(seed=st.integers(0, 10_000), n_dev=st.integers(2, 6))
@settings(**SETTINGS)
def test_migration_delay_triangle(seed, n_dev):
    """No-move placements cost zero; any move costs > 0."""
    blocks = make_blocks(4)
    cost = CostModel(d_model=512, n_heads=4)
    net = DeviceNetwork.sample(n_dev, seed=seed)
    rng = np.random.default_rng(seed)
    p = rng.integers(0, n_dev, len(blocks))
    assert migration_delay(p, p, blocks, cost, net, 2) == 0.0
    q = p.copy()
    q[0] = (q[0] + 1) % n_dev
    assert migration_delay(p, q, blocks, cost, net, 2) > 0.0


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_memory_usage_conserved(seed):
    """Sum of per-device memory == sum of block footprints, placement-free."""
    blocks = make_blocks(6)
    cost = CostModel(d_model=512, n_heads=6)
    net = DeviceNetwork.sample(4, seed=seed)
    rng = np.random.default_rng(seed)
    p1 = rng.integers(0, 4, len(blocks))
    p2 = rng.integers(0, 4, len(blocks))
    assert abs(memory_usage(p1, blocks, cost, net, 7).sum()
               - memory_usage(p2, blocks, cost, net, 7).sum()) < 1e-6


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_faster_devices_never_hurt(seed):
    """Uniformly doubling compute cannot increase the inference delay."""
    blocks = make_blocks(4)
    cost = CostModel(d_model=512, n_heads=4)
    net = DeviceNetwork.sample(3, seed=seed)
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 3, len(blocks))
    d1 = inference_delay(p, blocks, cost, net, 3)
    net2 = net.copy()
    net2.compute_avail = net2.compute_avail * 2
    assert inference_delay(p, blocks, cost, net2, 3) <= d1 + 1e-12


# -------------------------------------------------------------- algorithm
@given(seed=st.integers(0, 2_000), n_heads=st.sampled_from([2, 4, 8]),
       n_dev=st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_algorithm_output_is_valid_placement(seed, n_heads, n_dev):
    blocks = make_blocks(n_heads)
    cost = CostModel(d_model=512, n_heads=n_heads, n_layers=8,
                     compute_mode="incremental")
    net = DeviceNetwork.sample(n_dev, seed=seed)
    assigner = ResourceAwareAssigner(blocks, cost, deadline=1.0)
    place, stats = assigner.assign(net, 1, None)
    if place is not None:
        assert place.shape == (len(blocks),)
        assert ((0 <= place) & (place < n_dev)).all()
        # every block on exactly one device by construction; memory holds
        assert memory_usage(place, blocks, cost, net, 1).max() \
            <= net.mem_capacity.max() + 1e-6


# ------------------------------------------------------ placement bridge
@given(seed=st.integers(0, 10_000), n_heads=st.sampled_from([4, 8, 16]),
       n_slots=st.sampled_from([2, 4]))
@settings(**SETTINGS)
def test_placement_to_perm_is_permutation(seed, n_heads, n_slots):
    heads_per_slot = n_heads // n_slots
    blocks = make_blocks(n_heads)
    rng = np.random.default_rng(seed)
    place = rng.integers(0, n_slots, len(blocks))
    perm = placement_to_perm(place, blocks, n_slots, heads_per_slot)
    assert sorted(perm.tolist()) == list(range(n_heads))
    # idempotence: same placement -> no migrations
    assert migration_pairs(perm, perm, heads_per_slot) == []


# ----------------------------------------------------------- device churn
@given(seed=st.integers(0, 10_000),
       ops=st.lists(st.tuples(st.sampled_from(["fail", "rejoin", "slow",
                                               "join", "step"]),
                              st.integers(0, 10_000)),
                    min_size=1, max_size=12))
@settings(**SETTINGS)
def test_churn_sequences_keep_network_consistent(seed, ops):
    """Any interleaving of fail/rejoin/slow/join/background-step leaves
    the DeviceNetwork internally consistent: array shapes track the
    device count, inactive devices expose zero compute and zero usable
    memory, the link matrix stays square with an inf diagonal."""
    net = DeviceNetwork.sample(3, seed=seed)
    rng = np.random.default_rng(seed)
    for op, arg in ops:
        j = arg % net.n_devices
        if op == "fail" and net.n_active > 1 and net.is_active(j):
            net.fail(j)
        elif op == "rejoin" and not net.is_active(j):
            net.rejoin(j)
        elif op == "slow":
            net.slow(j, 1.0 + (arg % 50))
        elif op == "join":
            net.join(1e9 * (1 + arg % 4), 1e9,
                     np.full(net.n_devices, 1e8))
        elif op == "step":
            net.step_background_load()
        n = net.n_devices
        assert net.mem_capacity.shape == net.compute_max.shape \
            == net.compute_avail.shape == net.active.shape == (n,)
        assert net.bandwidth.shape == (n, n)
        assert np.all(np.isinf(np.diag(net.bandwidth)))
        assert np.all(net.compute_avail[~net.active] == 0.0)
        assert np.all(net.mem_usable()[~net.active] == 0.0)
        assert np.all(net.compute_avail <= net.compute_max + 1e-9)
        assert net.n_active == len(net.active_ids)
    del rng


@given(seed=st.integers(0, 2_000), kill=st.integers(0, 4),
       n_dev=st.integers(3, 5))
@settings(max_examples=15, deadline=None)
def test_assigner_never_places_on_inactive_device(seed, kill, n_dev):
    """After any failure the assigner's placements only target live
    devices — exclusion is enforced structurally, not priced."""
    blocks = make_blocks(4)
    cost = CostModel(d_model=512, n_heads=4, n_layers=8,
                     compute_mode="incremental")
    net = DeviceNetwork.sample(n_dev, seed=seed)
    net.fail(kill % n_dev)
    assigner = ResourceAwareAssigner(blocks, cost, deadline=1.0)
    place, _ = assigner.assign(net, 1, None)
    if place is not None:
        assert not np.any(place == kill % n_dev)
        assert np.all(net.active[place])


@given(seed=st.integers(0, 10_000), slot=st.integers(0, 3),
       factor=st.floats(1.0, 20.0))
@settings(**SETTINGS)
def test_monitor_availability_monotone_and_dead_zero(seed, slot, factor):
    """C_j(τ) from step-time telemetry: scaling one slot's observed step
    times up can only lower its availability estimate, and a dead slot
    estimates to exactly zero."""
    from repro.runtime.fault_tolerance import HeartbeatMonitor
    rng = np.random.default_rng(seed)
    base = rng.uniform(0.5, 2.0, size=4)

    def estimate(scale):
        mon = HeartbeatMonitor(4)
        for j in range(4):
            s = scale if j == slot else 1.0
            for _ in range(3):
                mon.record_step(j, float(base[j]) * s)
        return mon.availability(100.0)

    a1, a2 = estimate(1.0), estimate(factor)
    assert a2[slot] <= a1[slot] + 1e-9
    assert np.all(a1 >= 0) and np.all(a1 <= 100.0 + 1e-9)
    mon = HeartbeatMonitor(4)
    mon.mark_failed(slot)
    assert mon.availability(100.0)[slot] == 0.0


# ------------------------------------------------------------ HLO parsing
@given(dt=st.sampled_from(["bf16", "f32", "s32", "pred"]),
       dims=st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(**SETTINGS)
def test_shape_bytes(dt, dims):
    n = int(np.prod(dims)) if dims else 1
    per = {"bf16": 2, "f32": 4, "s32": 4, "pred": 1}[dt]
    assert _shape_bytes(dt, ",".join(map(str, dims))) == n * per


def test_collective_parser_trip_counts():
    hlo = """
cond.1 (p: (s32[])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%p, %c), direction=LT
}
body.1 (p: (s32[])) -> (s32[]) {
  %ar = f32[128]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[]) tuple(%i)
}
ENTRY main (a: f32[]) -> f32[] {
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  %ag = bf16[64,2]{1,0} all-gather(%y), dimensions={0}
  ROOT %r = f32[] constant(0)
}
"""
    d = collective_bytes(hlo)
    assert d["all-reduce"] == 7 * 128 * 4     # trip count applied
    assert d["all-gather"] == 64 * 2 * 2


# ------------------------------------------------------------ model layers
@given(seed=st.integers(0, 1000), window=st.sampled_from([0, 7, 64]))
@settings(max_examples=10, deadline=None)
def test_chunked_equals_vanilla_attention(seed, window):
    key = jax.random.PRNGKey(seed)
    B, S, Hp, KvE, dh = 1, 64, 2, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hp, dh))
    k = jax.random.normal(ks[1], (B, S, KvE, dh))
    v = jax.random.normal(ks[2], (B, S, KvE, dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = L.chunked_attention(q, k, v, pos, pos, NULL, causal=True,
                             window=window, chunk=16)
    o2 = L.attention_scores(q, k, v, L.causal_mask(pos, pos, window), NULL)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)


@given(frac=st.sampled_from([0.5, 1.0]),
       theta=st.sampled_from([1e4, 5e5]))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm_and_relativity(frac, theta):
    """RoPE is an isometry on the rotated sub-dim, and relative: shifting
    q and k positions together leaves the attention logits unchanged."""
    key = jax.random.PRNGKey(0)
    B, S, H, dh = 1, 8, 2, 16
    x = jax.random.normal(key, (B, S, H, dh))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = L.apply_rope(x, pos, theta, frac)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, dh))
    def logits(off):
        qp = L.apply_rope(q, pos + off, theta, frac)
        kp = L.apply_rope(k, pos + off, theta, frac)
        return jnp.einsum("bshd,bthd->bhst", qp, kp)
    np.testing.assert_allclose(np.asarray(logits(0)), np.asarray(logits(13)),
                               atol=1e-3, rtol=1e-3)
