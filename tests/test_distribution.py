"""Distribution layer: param-spec rules, HLO analyzer fidelity, and an
actual sharded lower+compile on a 16-virtual-device mesh (subprocess so the
main process keeps 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.placement_bridge import param_spec
from repro.launch.hlo_analysis import collective_bytes, full_analysis


# ------------------------------------------------------------- spec rules
def test_param_spec_rules_tp():
    cfg = get_config("llama3-8b")
    # stacked attn weights: (L, D, Hp, dh)
    assert param_spec(["layers", "attn", "wq"], 4, cfg, 16,
                      fsdp=True, pod_ep=False) == P(None, "data", "model", None)
    assert param_spec(["layers", "attn", "wo"], 4, cfg, 16,
                      fsdp=False, pod_ep=False) == P(None, "model", None, None)
    # kv weights with kv=8 < tp=16: head axis NOT sharded (replicated small)
    assert param_spec(["layers", "attn", "wk"], 4, cfg, 16,
                      fsdp=False, pod_ep=False)[2] is None
    assert param_spec(["tok_embed"], 2, cfg, 16, fsdp=False,
                      pod_ep=False) == P("model", None)
    # moe experts on the multi-pod mesh get EP over pod
    mx = get_config("mixtral-8x7b")
    sp = param_spec(["layers", "moe", "w_gate"], 4, mx, 16,
                    fsdp=True, pod_ep=True)
    assert sp == P(None, "pod", "data", "model")


def test_param_spec_rules_quant_and_zero3():
    cfg = get_config("llama3-8b")
    # quantized leaves follow the parent weight's rule
    assert param_spec(["layers", "attn", "wq", "q8"], 4, cfg, 16,
                      fsdp=False, pod_ep=False) == P(None, None, "model", None)
    sc = param_spec(["layers", "attn", "wq", "sc"], 2, cfg, 16,
                    fsdp=False, pod_ep=False)
    assert sc == P(None, None)  # per-(layer,dh) scale: dh not sharded
    # zero3: largest 256-divisible dim carries the full mesh
    z = param_spec(["layers", "mlp", "w_gate"], 3, cfg, 16, fsdp=False,
                   pod_ep=False, layout="zero3",
                   shape=(32, 4096, 14336), n_devices=256)
    assert z == P(None, None, ("data", "model"))
    # indivisible dims fall back to model-only or replicated
    z2 = param_spec(["layers", "attn", "wo"], 4, cfg, 16, fsdp=False,
                    pod_ep=False, layout="zero3",
                    shape=(32, 32, 128, 4096), n_devices=256)
    assert z2 == P(None, None, None, ("data", "model"))


# --------------------------------------------------------- HLO analyzer
def test_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c
    x = jnp.ones((128, 128))
    c = jax.jit(f).lower(x, x).compile()
    fa = full_analysis(c.as_text())
    assert abs(fa["dot_flops"] - 10 * 2 * 128 ** 3) < 2 * 128 ** 3


def test_collective_promotion_halved():
    hlo = textwrap.dedent("""
    ENTRY main (a: f32[]) -> f32[] {
      %ar1 = f32[64]{0} all-reduce(%x), replica_groups={}, to_apply=%add.clone_promoted
      %ar2 = f32[64]{0} all-reduce(%y), replica_groups={}, to_apply=%add
      ROOT %r = f32[] constant(0)
    }
    """)
    d = collective_bytes(hlo)
    assert d["all-reduce"] == 64 * 4 * 0.5 + 64 * 4


# ------------------------------------------------- sharded compile (16 dev)
@pytest.mark.slow  # opt in with `-m slow` (or RUN_SLOW_TESTS=1 scripts/ci.sh)
def test_sharded_train_step_compiles_16dev():
    """Reduced llama3 train step lowers+compiles on a (4,4) mesh with the
    production sharding rules (subprocess: device count is process-global).

    Gated behind the registered ``slow`` marker — deselected by the default
    tier-1 profile (see pyproject.toml): a subprocess spinning up 16
    virtual XLA devices is environment-sensitive and was the seed suite's
    420 s timeout.  The compiled shape is kept small (seq 32, d_model 128)
    so the opted-in run finishes in seconds."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import json
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.core.placement_bridge import batch_shardings, param_shardings
        from repro.launch.hlo_analysis import full_analysis, collective_bytes
        from repro.launch.steps import make_train_step
        from repro.models.api import build_model
        from repro.models.partitioning import make_partitioner
        from repro.optim.adamw import AdamW, AdamWState

        cfg = get_config("llama3-8b").with_overrides(
            n_layers=2, d_model=128, d_ff=256, n_heads=8, n_kv_heads=4,
            d_head=16, vocab_size=512)
        mesh = jax.make_mesh((4, 4), ("data", "model"))
        part = make_partitioner(mesh, fsdp=True, sp=True)
        model = build_model(cfg, tp=4, part=part, remat="full")
        opt = AdamW()
        params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_sh = param_shardings(params_s, cfg, mesh, fsdp=True)
        opt_s = jax.eval_shape(opt.init, params_s)
        o_sh = AdamWState(step=NamedSharding(mesh, P()),
                          mu=param_shardings(opt_s.mu, cfg, mesh, fsdp=True),
                          nu=param_shardings(opt_s.nu, cfg, mesh, fsdp=True))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        b_sh = batch_shardings(batch, mesh)
        fn = jax.jit(make_train_step(model, opt),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())))
        with mesh:
            compiled = fn.lower(params_s, opt_s, batch).compile()
        hlo = compiled.as_text()
        fa = full_analysis(hlo)
        cb = collective_bytes(hlo)
        cb.pop("_counts")
        print(json.dumps({"flops": fa["dot_flops"],
                          "coll": sum(cb.values())}))
    """)
    # JAX_PLATFORMS must be pinned: without it jax probes for accelerator
    # plugins in the bare env and can hang past the subprocess timeout —
    # this, plus optimization_barrier lacking a differentiation rule
    # (fixed via layers.pin_layer_slice), was the seed-suite timeout.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["flops"] > 0 and stats["coll"] > 0
