"""Seeded workload driver: arrival processes, virtual clock, and the
deterministic load loop the tail-latency benchmarks gate on."""
import numpy as np
import pytest

from repro.serving.engine import ServingEngine
from repro.serving.workload import (VirtualClock, diurnal_arrivals,
                                    drive_virtual, make_workload,
                                    mmpp_arrivals, offered_load,
                                    poisson_arrivals)
from tests.conftest import reduced_config


# ---------------------------------------------------------------- processes
@pytest.mark.parametrize("process", ["poisson", "bursty", "diurnal"])
def test_same_seed_same_workload(process):
    """CI gates strict-tolerance percentiles on this: equal seeds must
    yield byte-equal arrival times, prompts, and token budgets."""
    a = make_workload(process, rate=0.3, horizon=80.0, seed=7)
    b = make_workload(process, rate=0.3, horizon=80.0, seed=7)
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert ra.t_arrival == rb.t_arrival
        assert ra.max_new_tokens == rb.max_new_tokens
        assert np.array_equal(ra.prompt, rb.prompt)
    c = make_workload(process, rate=0.3, horizon=80.0, seed=8)
    assert [r.t_arrival for r in c] != [r.t_arrival for r in a]


def test_poisson_rate_and_ordering():
    rng = np.random.default_rng(0)
    t = poisson_arrivals(0.5, 4000.0, rng)
    assert np.all(np.diff(t) > 0) and t[0] >= 0 and t[-1] < 4000.0
    # LLN: observed rate within 10% of nominal over a long horizon
    assert len(t) / 4000.0 == pytest.approx(0.5, rel=0.1)


def test_mmpp_is_burstier_than_poisson():
    """Same-ish mean load, heavier inter-arrival tail: the squared
    coefficient of variation of MMPP gaps must exceed Poisson's (~1)."""
    rng = np.random.default_rng(1)
    gaps = np.diff(mmpp_arrivals(0.2, 2.0, 50.0, 8000.0, rng))
    cv2 = np.var(gaps) / np.mean(gaps) ** 2
    assert cv2 > 1.3


def test_diurnal_peaks_at_half_period():
    """Thinned sinusoid: the rate troughs at t=0 and peaks at period/2,
    so the middle half of each period must hold more arrivals."""
    rng = np.random.default_rng(2)
    period = 1000.0
    t = diurnal_arrivals(0.1, 1.0, period, 4000.0, rng)
    phase = np.mod(t, period) / period
    peak = np.sum((phase > 0.25) & (phase < 0.75))
    trough = len(t) - peak
    assert peak > 2 * trough


def test_offered_load_counts_prompt_and_output():
    reqs = make_workload("poisson", rate=0.5, horizon=60.0, seed=3)
    off = offered_load(reqs, 60.0)
    assert off["req_rate"] == pytest.approx(len(reqs) / 60.0)
    toks = sum(len(r.prompt) + r.max_new_tokens for r in reqs)
    assert off["tok_rate"] == pytest.approx(toks / 60.0)


def test_virtual_clock_monotone():
    c = VirtualClock()
    c.advance(2.0)
    c.advance_to(1.0)          # advance_to never rewinds
    assert c.now() == 2.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_unknown_process_rejected():
    with pytest.raises(ValueError, match="arrival process"):
        make_workload("adversarial", rate=1.0, horizon=10.0)


# ------------------------------------------------------------------- driver
def test_drive_virtual_deterministic_and_complete():
    """Two identical engine+workload runs produce identical percentile
    metrics and identical streams — the property that lets CI gate
    p50/p95/p99 at the strict tolerance."""
    cfg = reduced_config("llama3-8b")
    reqs = make_workload("poisson", rate=0.3, horizon=30.0, seed=5,
                         vocab=cfg.vocab_size)
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=10 ** 9,
                            seed=0, paged=True, page_size=8)
        outs.append(drive_virtual(eng, reqs))
    a, b = outs
    assert a["n_finished"] == len(reqs) == a["n_submitted"]
    assert a["streams"] == b["streams"]
    for k in ("p50_ttft", "p95_ttft", "p99_ttft", "p50_itl", "p99_itl",
              "goodput", "steps", "t_end"):
        assert a[k] == b[k], k
    # TTFT includes queueing delay, so it is at least one step for the
    # later arrivals and percentiles are ordered
    assert a["p99_ttft"] >= a["p95_ttft"] >= a["p50_ttft"] >= 0.0
    # the sink is restored after the drive
    assert eng.token_sink is None


def test_drive_virtual_load_ordering():
    """Higher offered load on the same engine never improves the p99
    TTFT — queueing delay is monotone in arrival rate (seed held)."""
    cfg = reduced_config("llama3-8b")
    tails = []
    for rate in (0.1, 0.6):
        reqs = make_workload("poisson", rate=rate, horizon=40.0, seed=9,
                             vocab=cfg.vocab_size)
        eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=10 ** 9,
                            seed=0, paged=True, page_size=8)
        m = drive_virtual(eng, reqs)
        assert m["n_finished"] == len(reqs)
        tails.append(m["p99_ttft"])
    assert tails[1] > tails[0]
