"""benchmarks/run.py --check regression gate: the row sets must match the
committed baseline EXACTLY — baseline rows missing from a run fail
(coverage loss) and fresh rows missing from the baseline fail too (an
ungated row used to pass silently)."""
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:          # benchmarks/ is not a package
    sys.path.insert(0, str(REPO_ROOT))      # importable from src/ alone

from benchmarks.run import check_group  # noqa: E402


def _write_baseline(tmp_path, key, rows):
    path = tmp_path / f"BENCH_{key}.json"
    path.write_text(json.dumps(rows))
    return str(tmp_path)


def _row(name, us=100.0, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived}


def test_matching_rows_pass(tmp_path):
    base = [_row("g/a", derived="tok_s=10.0"), _row("g/b")]
    d = _write_baseline(tmp_path, "g", base)
    fresh = [_row("g/a", derived="tok_s=10.5"), _row("g/b", us=101.0)]
    assert check_group("g", fresh, d, 0.15, 0.15) == []


def test_baseline_row_missing_from_run_fails(tmp_path):
    d = _write_baseline(tmp_path, "g", [_row("g/a"), _row("g/b")])
    fails = check_group("g", [_row("g/a")], d, 0.15, 0.15)
    assert any("coverage loss" in f and "g/b" in f for f in fails)


def test_new_row_name_fails_closed_and_is_listed(tmp_path):
    """The former hole: a run whose group gained a new row name sailed
    through ungated.  Now every unmatched row is listed in one clear
    failure telling the user to refresh the baseline."""
    d = _write_baseline(tmp_path, "g", [_row("g/a")])
    fresh = [_row("g/a"), _row("g/renamed"), _row("g/brand_new")]
    fails = check_group("g", fresh, d, 0.15, 0.15)
    assert len(fails) == 1
    assert "not in the baseline" in fails[0]
    assert "g/brand_new" in fails[0] and "g/renamed" in fails[0]
    assert "--json" in fails[0]          # the remediation is spelled out


def test_rename_fails_on_both_sides(tmp_path):
    """A renamed row reads as coverage loss on one side and an unmatched
    new row on the other — both must surface."""
    d = _write_baseline(tmp_path, "g", [_row("g/old")])
    fails = check_group("g", [_row("g/new")], d, 0.15, 0.15)
    assert any("g/old" in f and "coverage loss" in f for f in fails)
    assert any("g/new" in f and "not in the baseline" in f for f in fails)


def test_metric_regression_still_fails(tmp_path):
    d = _write_baseline(tmp_path, "g", [_row("g/a", derived="tok_s=10.0")])
    fails = check_group("g", [_row("g/a", derived="tok_s=8.0")], d,
                        0.15, 0.15)
    assert any("tok_s" in f for f in fails)
    # improvements pass
    assert check_group("g", [_row("g/a", derived="tok_s=12.0")], d,
                       0.15, 0.15) == []


# ----------------------------------------------- percentile latency gating
PCTL = ("p50_ttft=2.00;p95_ttft=8.00;p99_ttft=20.00;"
        "goodput=3.000;offered_load=0.450")


def test_percentile_metrics_gate_lower_is_better(tmp_path):
    d = _write_baseline(tmp_path, "load", [_row("load/a", derived=PCTL)])
    # p99 up 50% -> regression; p50/p95 unchanged
    worse = PCTL.replace("p99_ttft=20.00", "p99_ttft=30.00")
    fails = check_group("load", [_row("load/a", derived=worse)],
                        d, 0.15, 0.15)
    assert len(fails) == 1 and "p99_ttft" in fails[0]
    # lower percentiles are an improvement, never a failure
    better = PCTL.replace("p99_ttft=20.00", "p99_ttft=5.00")
    assert check_group("load", [_row("load/a", derived=better)],
                       d, 0.15, 0.15) == []


def test_percentile_failure_names_offered_load(tmp_path):
    """A tail-latency number is meaningless without the load that drove
    it — the failure message must carry the row's offered_load."""
    d = _write_baseline(tmp_path, "load", [_row("load/a", derived=PCTL)])
    worse = PCTL.replace("p95_ttft=8.00", "p95_ttft=80.00")
    fails = check_group("load", [_row("load/a", derived=worse)],
                        d, 0.15, 0.15)
    assert any("offered_load=0.45" in f for f in fails)


def test_percentiles_use_strict_tol_not_wall(tmp_path):
    """Virtual-clock percentiles are deterministic: the wide wall
    tolerance must NOT apply to them (a 40% p99 regression fails even
    when wall rows are allowed 60%)."""
    d = _write_baseline(tmp_path, "load", [_row("load/a", derived=PCTL)])
    worse = PCTL.replace("p99_ttft=20.00", "p99_ttft=28.00")
    fails = check_group("load", [_row("load/a", derived=worse)],
                        d, 0.15, 0.60)
    assert any("p99_ttft" in f for f in fails)


def test_goodput_gates_higher_is_better(tmp_path):
    d = _write_baseline(tmp_path, "load", [_row("load/a", derived=PCTL)])
    worse = PCTL.replace("goodput=3.000", "goodput=2.000")
    fails = check_group("load", [_row("load/a", derived=worse)],
                        d, 0.15, 0.15)
    assert any("goodput" in f for f in fails)
    # offered_load itself is context, not a gated metric: a sweep point
    # change shows up through the row SET, not a direction gate
    shifted = PCTL.replace("offered_load=0.450", "offered_load=0.500")
    assert check_group("load", [_row("load/a", derived=shifted)],
                       d, 0.15, 0.15) == []
