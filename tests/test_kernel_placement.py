"""Placement-driven resident-slice decode kernel: interpret-mode parity
against the jnp oracle over ragged per-layer placements, index-map
plumbing (``placement_to_head_slices`` / ``head_row_maps``), and the
serving engine's ``use_kernel=True`` stream equivalence before and after
applied migrations.

Hypothesis cases (ragged per-layer head splits, GQA group sizes,
post-migration rebuilds) skip cleanly when hypothesis is absent; the
deterministic parametrizations below keep the same surfaces covered."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.blocks import make_blocks, graph_of
from repro.core.network import DeviceNetwork
from repro.core.placement_bridge import (head_row_maps, identity_head_rows,
                                         placement_to_head_slices,
                                         placement_to_perms)
from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_int8_resident,
                                            decode_attention_resident)
from tests.conftest import reduced_config

KEY = jax.random.PRNGKey(7)


def _ragged_place(n_heads, n_layers, splits, n_slots):
    """Block placement with layer l's heads split per ``splits[l]`` (a
    tuple of per-slot counts summing to n_heads); proj/ffn on slot 0."""
    blocks = make_blocks(n_heads, n_layers)
    place = np.zeros(len(blocks), dtype=int)
    g = graph_of(blocks)
    for l, split in enumerate(splits):
        assert sum(split) == n_heads and len(split) == n_slots
        hid = 0
        for s, cnt in enumerate(split):
            for _ in range(cnt):
                place[g.heads[l][hid].index] = s
                hid += 1
    return blocks, place


# ------------------------------------------------------- per-slot dispatch
@pytest.mark.parametrize("H,KvE,splits", [
    (8, 8, [(1, 7), (5, 3)]),            # MHA, skewed + flipped
    (8, 4, [(2, 6), (6, 2)]),            # GQA 2:1
    (8, 2, [(4, 4), (8, 0)]),            # GQA 4:1, one empty slot
])
def test_per_slot_resident_dispatch_matches_oracle(H, KvE, splits):
    """Each slot runs grid (B, H_res, nk) over only its resident rows —
    the union over slots reproduces the full-oracle output exactly (no
    padding to the global H, empty slots dispatch nothing)."""
    B, T, dh, n_slots = 2, 128, 32, 2
    n_layers = len(splits)
    blocks, place = _ragged_place(H, n_layers, splits, n_slots)
    slices = placement_to_head_slices(place, blocks, n_slots)
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, KvE, T, dh))
    v = jax.random.normal(ks[2], (B, KvE, T, dh))
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    want = ref.decode_attention_ref(q, k, v, lens)
    for l in range(n_layers):
        got = np.zeros((B, H, dh), np.float32)
        covered = []
        for s in range(n_slots):
            rows = slices[l][s]
            assert len(rows) == splits[l][s]     # ragged grid, not padded
            if not len(rows):
                continue
            out = decode_attention_resident(q, k, v, lens,
                                            jnp.asarray(rows), bk=64,
                                            interpret=True)
            got[:, rows] = np.asarray(out)
            covered.extend(rows.tolist())
        assert sorted(covered) == list(range(H))
        np.testing.assert_allclose(got, np.asarray(want, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_int8_resident_kernel_in_sync():
    """The fused int8-KV variant accepts the same gather maps and matches
    the dequantized-cache oracle on a ragged slice."""
    B, H, KvE, T, dh = 2, 4, 2, 128, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, KvE, T, dh))
    v = jax.random.normal(ks[2], (B, KvE, T, dh))

    def q8(t):
        sc = jnp.maximum(jnp.abs(t).max(-1), 1e-8) / 127.0
        return (jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
                .astype(jnp.int8), sc)

    kq, ksc = q8(k)
    vq, vsc = q8(v)
    lens = jax.random.randint(ks[3], (B,), 1, T + 1)
    rows = jnp.asarray([3, 1, 0])                # ragged + out of order
    out = decode_attention_int8_resident(q, kq, ksc, vq, vsc, lens, rows,
                                         bk=64, interpret=True)
    kd = kq.astype(jnp.float32) * ksc[..., None]
    vd = vq.astype(jnp.float32) * vsc[..., None]
    want = ref.decode_attention_ref(q, kd, vd, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want)[:, rows],
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------- row-map logic
def test_head_row_maps_cover_invert_and_follow_perms():
    H, n_layers, n_slots = 8, 3, 4
    blocks, place = _ragged_place(
        H, n_layers, [(1, 3, 2, 2), (4, 0, 2, 2), (2, 2, 2, 2)], n_slots)
    rows, inv = head_row_maps(place, blocks, n_slots, H)
    assert rows.shape == inv.shape == (n_layers, H)
    for l in range(n_layers):
        assert sorted(rows[l].tolist()) == list(range(H))   # a permutation
        np.testing.assert_array_equal(rows[l][inv[l]], np.arange(H))
    # after a physical migration the maps must point at the NEW positions:
    # logical head perms[l][p] sits at physical position p
    perms = placement_to_perms(place, blocks, n_slots, H // n_slots)
    prow, _ = head_row_maps(place, blocks, n_slots, H, perms=perms)
    for l in range(n_layers):
        inv_perm = np.argsort(perms[l])
        np.testing.assert_array_equal(prow[l], inv_perm[rows[l]])


def test_identity_head_rows_roundtrip():
    rows, inv = identity_head_rows(2, 4)
    np.testing.assert_array_equal(rows, inv)
    np.testing.assert_array_equal(rows[0], np.arange(4))


def test_placement_slices_are_the_cost_models_truth():
    """The slices cover exactly the heads the cost model prices per layer
    — same blocks, same placement array, one source of truth."""
    H, n_layers, n_slots = 4, 2, 2
    blocks, place = _ragged_place(H, n_layers, [(1, 3), (3, 1)], n_slots)
    slices = placement_to_head_slices(place, blocks, n_slots)
    g = graph_of(blocks)
    for l in range(n_layers):
        for s in range(n_slots):
            for h in slices[l][s]:
                blk = g.heads[l][h]
                assert blk.head_id == h and int(place[blk.index]) == s


# ------------------------------------------------------ hypothesis parity
def test_resident_kernel_parity_hypothesis():
    """Hypothesis-drawn ragged per-layer splits, GQA group sizes and a
    post-migration index-map rebuild all stay allclose to the oracle."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(data=st.data(),
           kv=st.sampled_from([1, 2, 4]),
           n_layers=st.integers(1, 3),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def inner(data, kv, n_layers, seed):
        H, n_slots, B, T, dh = 8, 2, 2, 64, 16
        KvE = H // kv if kv > 1 else H
        splits = []
        for _ in range(n_layers):
            a = data.draw(st.integers(0, H))
            splits.append((a, H - a))
        blocks, place = _ragged_place(H, n_layers, splits, n_slots)
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (B, H, dh))
        k = jax.random.normal(ks[1], (B, KvE, T, dh))
        v = jax.random.normal(ks[2], (B, KvE, T, dh))
        lens = jax.random.randint(ks[3], (B,), 1, T + 1)
        want = np.asarray(ref.decode_attention_ref(q, k, v, lens))
        group = H // KvE
        perms = placement_to_perms(place, blocks, n_slots, H // n_slots,
                                   group_size=group)
        # physical migration: permute q rows by perms, kv rows by the
        # induced group permutation (group-consistent layouts keep
        # kv_row == q_row // G)
        for use_perms in (None, perms):
            rows, inv = head_row_maps(place, blocks, n_slots, H,
                                      perms=use_perms)
            for l in range(n_layers):
                if use_perms is None:
                    qp, kp, vp = q, k, v
                else:
                    qp = q[:, perms[l]]
                    kvp = perms[l].reshape(-1, group)[:, 0] // group
                    kp, vp = k[:, kvp], v[:, kvp]
                out = decode_attention_resident(
                    qp, kp, vp, lens, jnp.asarray(rows[l]), bk=32,
                    interpret=True)
                got = np.asarray(out)[:, inv[l]]        # back to phys order
                if use_perms is not None:
                    got = got[:, np.argsort(perms[l])]  # back to logical
                np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)

    inner()


# ------------------------------------------------- engine stream parity
def _run_engine(cfg, prompts, *, lam, straggle_at, use_kernel, n_dev=2):
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, n_slots=2, max_seq=64, lam=lam, seed=0,
                        net=DeviceNetwork.sample(n_dev, seed=1),
                        use_kernel=use_kernel)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
    while True:
        if straggle_at is not None and eng.decode_steps == straggle_at:
            dev = int(eng.controller.head_counts().argmax())
            eng.net.inject_straggler(dev, slowdown=500.0)
        if not eng.step():
            break
    return {r.rid: r.out_tokens for r in eng.finished}, eng


def test_engine_streams_match_jnp_path_across_migration():
    """Acceptance: ``ServingEngine(use_kernel=True)`` greedy streams equal
    the jnp path on a multi-layer GQA model, with at least one migration
    physically applied mid-serve (the kernel grid is rebuilt from the
    controller's plan) and equal to a migration-free run."""
    cfg = reduced_config("llama3-8b", n_layers=3, n_kv_heads=2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]
    kern, eng = _run_engine(cfg, prompts, lam=3, straggle_at=4,
                            use_kernel=True)
    jnp_, _ = _run_engine(cfg, prompts, lam=3, straggle_at=4,
                          use_kernel=False)
    free, _ = _run_engine(cfg, prompts, lam=10 ** 9, straggle_at=None,
                          use_kernel=True)
    assert kern == jnp_ == free and len(kern) == 5
    applied = [e for e in eng.migration_log
               if e["applied"] and e["n_migrations"]]
    assert applied, "no migration was physically applied"
    # the maps were rebuilt from the plan: the physical layout moved and
    # every per-layer row map is still a permutation of the head rows
    assert eng._phys_perms is not None
    Hp = eng.model.hd.Hp
    assert any(not np.array_equal(p, np.arange(Hp))
               for p in eng._phys_perms)
    for l in range(cfg.n_layers):
        assert sorted(eng._head_rows[l].tolist()) == list(range(Hp))


def test_engine_decode_state_carries_row_maps():
    cfg = reduced_config("musicgen-large", n_layers=3)
    from repro.serving.engine import ServingEngine
    eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0,
                        use_kernel=True)
    st = eng.state
    assert st["head_rows"].shape == (3, eng.model.hd.Hp)
    assert st["head_inv"].shape == (3, eng.model.hd.Hp)
    # and a kernel-less engine carries none (jnp path unchanged)
    eng0 = ServingEngine(cfg, n_slots=2, max_seq=48, lam=10 ** 9, seed=0)
    assert "head_rows" not in eng0.state


def test_engine_use_kernel_geometry_guard():
    """Placement-derived grids need the bridge's head-position space to
    equal the model's padded head count — typed reject at construction."""
    from repro.serving.engine import ServingEngine, UnsupportedArchError
    cfg = reduced_config("llama3-8b")            # 4 heads
    with pytest.raises(UnsupportedArchError, match="head-position"):
        ServingEngine(cfg, n_slots=2, max_seq=32, seed=0, use_kernel=True,
                      net=DeviceNetwork.sample(8, seed=0))  # 8 positions


def test_cross_attention_kernel_parity_nonzero_gate():
    """VLM cross-attention decode through the kernel: prefix-masked image
    K/V, non-zero gate — allclose to the jnp path, including a fully
    masked (text-only) row, which the jnp path resolves to the uniform
    average of V rather than zero."""
    from repro.models import layers as L
    from repro.models.partitioning import NULL
    cfg = reduced_config("llama-3.2-vision-11b")
    hd = L.head_dims(cfg, 1)
    p = L.init_attention(jax.random.PRNGKey(3), cfg, hd, cross=True)
    p["gate"] = jnp.asarray(0.7)
    B, I = 3, 8
    x = jax.random.normal(jax.random.PRNGKey(4), (B, 1, cfg.d_model))
    kv = jax.random.normal(jax.random.PRNGKey(5), (B, I, cfg.d_model))
    mask = np.zeros((B, I), bool)
    mask[0, :5] = True                           # prefix-valid rows
    mask[1, :I] = True                           # row 2 stays all-masked
    out_j, cache = L.cross_attention_block(cfg, p, hd, x, NULL,
                                           kv_embeds=kv,
                                           kv_mask=jnp.asarray(mask))
    out_k, _ = L.cross_attention_block(cfg, p, hd, x, NULL, kv_cache=cache,
                                       kv_mask=jnp.asarray(mask),
                                       use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               atol=3e-5, rtol=3e-5)


def test_zamba2_use_kernel_decode_parity():
    """The hybrid family forwards use_kernel to its shared attention
    block (identity grid — one shared block, no per-layer row maps):
    decode logits must match the jnp path instead of silently ignoring
    the flag."""
    from repro.models.api import build_model
    cfg = reduced_config("zamba2-2.7b")
    ref = build_model(cfg)
    ker = build_model(cfg, use_kernel=True)
    assert ker.use_kernel
    params = ref.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    logits_r, st_r = ref.prefill(params, ref.init_decode_state(params, 2, 16),
                                 toks)
    logits_k, st_k = ker.prefill(params, ker.init_decode_state(params, 2, 16),
                                 toks)
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits_r),
                               atol=1e-5, rtol=1e-5)
    nxt = jnp.argmax(logits_r, axis=-1)
    for _ in range(3):
        logits_r, st_r = ref.decode_step(params, st_r, nxt)
        logits_k, st_k = ker.decode_step(params, st_k, nxt)
        np.testing.assert_allclose(np.asarray(logits_k),
                                   np.asarray(logits_r),
                                   atol=3e-5, rtol=3e-5)
        nxt = jnp.argmax(logits_r, axis=-1)


def test_cross_attention_kernel_rejects_non_prefix_mask():
    """The kernel path models validity as per-row lengths, so a concrete
    scattered (non-right-padded) kv_mask must be refused eagerly rather
    than silently attending to the wrong slots."""
    from repro.models import layers as L
    from repro.models.partitioning import NULL
    cfg = reduced_config("llama-3.2-vision-11b")
    hd = L.head_dims(cfg, 1)
    p = L.init_attention(jax.random.PRNGKey(3), cfg, hd, cross=True)
    B, I = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(4), (B, 1, cfg.d_model))
    kv = jax.random.normal(jax.random.PRNGKey(5), (B, I, cfg.d_model))
    _, cache = L.cross_attention_block(cfg, p, hd, x, NULL, kv_embeds=kv)
    mask = np.zeros((B, I), bool)
    mask[0, ::2] = True                          # scattered, not a prefix
    mask[1, :I] = True
    with pytest.raises(ValueError, match="prefix"):
        L.cross_attention_block(cfg, p, hd, x, NULL, kv_cache=cache,
                                kv_mask=jnp.asarray(mask), use_kernel=True)
