#!/usr/bin/env bash
# Tier-1 CI: lint, install dev deps (best-effort when offline), run the
# test suite in ONE pytest invocation, then gate the benchmark smoke
# against the committed baselines (benchmarks/baselines/).
set -euo pipefail
cd "$(dirname "$0")/.."

# 1. dev deps (ruff included): best-effort offline, but never swallow
#    the error text
if ! pip_log=$(python -m pip install -q -r requirements-dev.txt 2>&1); then
    echo "[ci] pip install failed (offline?) — using preinstalled deps:"
    echo "${pip_log}"
fi

# 2. lint — the first CHECK, fails fast before the multi-minute suite.
#    (After the install so a fresh container actually has ruff; an
#    offline container without it skips with a notice instead of lying.)
if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "[ci] ruff not installed — lint skipped (pip install ruff)"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 3. hot-path auditor: repo-invariant RPR lints, jaxpr audit of the
#    jitted hot functions, and the optimized-HLO audit of the compiled
#    decode path against src/repro/analysis/baselines.json.  A FAILING
#    gate: unwaived findings exit non-zero before the suite runs.
python -m repro.analysis

# 4. one pytest invocation: the default profile deselects slow tests
#    (pyproject addopts); RUN_SLOW_TESTS=1 widens the -m expression so
#    slow AND fast run in the same session instead of two from-scratch
#    suite runs.
if [[ "${RUN_SLOW_TESTS:-0}" == "1" ]]; then
    python -m pytest -x -q -m "slow or not slow" "$@"
else
    python -m pytest -x -q "$@"
fi

# 5. benchmark smoke + regression gate: output stays visible (failures
#    used to vanish into /dev/null) and a >15% latency / tokens-per-sec
#    regression vs the committed baselines fails the build.  Raw
#    wall-clock rows are only comparable within one machine class, so
#    they default to a loose gate here (the deterministic tok_s / x_* /
#    ratio_to_exact metrics stay at the strict 15%); override by
#    exporting BENCH_CHECK_TOL_WALL.
export BENCH_CHECK_TOL_WALL="${BENCH_CHECK_TOL_WALL:-0.60}"
python -m benchmarks.run \
    --only small_scale,pipelined,kernel_decode,pipeline_search,paged_serving,moe_serving,serving_load,elastic_serving,roofline \
    --check benchmarks/baselines
