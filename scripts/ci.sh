#!/usr/bin/env bash
# Tier-1 CI: install dev deps (best-effort when offline) and run the
# default test profile (slow tests deselected; RUN_SLOW_TESTS=1 opts in).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "[ci] pip install failed (offline?) — using preinstalled deps"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${RUN_SLOW_TESTS:-0}" == "1" ]]; then
    python -m pytest -x -q -m "slow" "$@"
fi
python -m pytest -x -q "$@"

# benchmark smoke: the tiny-shape exact-solver group and the pipelined-
# decode group must keep running (catches benchmark bit-rot without paying
# for the full figure sweeps)
python -m benchmarks.run --only small_scale,pipelined > /dev/null
