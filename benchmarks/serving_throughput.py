"""Continuous vs. wave batching on a mixed-prompt-length, staggered-arrival
workload (acceptance: continuous >= 1.2x wave tokens/sec on the default
config).

The workload is the one static batching is worst at and production traffic
actually looks like: prompts of many distinct lengths arriving over time.
The wave engine pays three ways — head-of-line blocking (a wave only
admits equal-length prompts), dead slots (a finished request's slot idles
until the wave drains), and a fresh prefill compile per distinct prompt
length.  The continuous engine admits any request into any free slot,
keeps the batch full, and bounds compiles via bucketed prefill.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--requests 12]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingEngine, WaveServingEngine


def default_cfg():
    return get_config("llama3-8b").with_overrides(
        n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4,
        d_head=16, vocab_size=97, dtype="float32", param_dtype="float32")


def make_workload(n_requests: int, seed: int = 0):
    """(prompt, max_new_tokens, arrival_step) triples: mixed lengths,
    staggered arrivals every few decode steps."""
    rng = np.random.default_rng(seed)
    lengths = [4, 6, 8, 10, 12, 14]
    out = []
    for i in range(n_requests):
        L = lengths[i % len(lengths)]
        prompt = rng.integers(0, 97, size=L).astype(np.int32)
        toks = int(rng.integers(8, 20))
        out.append((prompt, toks, 3 * i))
    return out


def drive(eng, workload, max_steps: int = 20_000) -> dict:
    """Feed arrivals as decode progresses; drain; report throughput."""
    pending = list(workload)
    t0 = time.monotonic()
    while pending or eng.queue or getattr(eng, "slots", None) and \
            any(s is not None for s in eng.slots):
        while pending and pending[0][2] <= eng.decode_steps:
            prompt, toks, _ = pending.pop(0)
            eng.submit(prompt, max_new_tokens=toks)
        if isinstance(eng, WaveServingEngine):
            wave = eng._next_wave()
            if wave:
                eng._run_wave(wave, max_steps)
            elif pending:      # idle: jump to the next arrival (favors wave)
                prompt, toks, _ = pending.pop(0)
                eng.submit(prompt, max_new_tokens=toks)
            else:
                break
        else:
            progressed = eng.step()
            if not progressed:
                if pending:    # idle: jump to the next arrival
                    prompt, toks, _ = pending.pop(0)
                    eng.submit(prompt, max_new_tokens=toks)
                else:
                    break
        if eng.decode_steps >= max_steps:
            break
    wall = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in eng.finished)
    return {"requests": len(eng.finished), "tokens": toks, "wall_s": wall,
            "tok_per_s": toks / max(wall, 1e-9),
            "decode_steps": eng.decode_steps,
            "slot_util": (eng.slot_busy_steps
                          / max(eng.decode_steps * eng.n_slots, 1)
                          if hasattr(eng, "slot_busy_steps") else None)}


def run(n_requests: int = 12, n_slots: int = 4, max_seq: int = 64,
        seed: int = 0, verbose: bool = True) -> dict:
    cfg = default_cfg()
    results = {}
    for name, cls in (("wave", WaveServingEngine),
                      ("continuous", ServingEngine)):
        eng = cls(cfg, n_slots=n_slots, max_seq=max_seq, lam=10 ** 9,
                  seed=seed)
        results[name] = drive(eng, make_workload(n_requests, seed))
    speedup = results["continuous"]["tok_per_s"] / \
        max(results["wave"]["tok_per_s"], 1e-9)
    results["speedup"] = speedup
    if verbose:
        print(f"{'engine':<12} {'req':>4} {'tokens':>7} {'wall_s':>8} "
              f"{'tok/s':>8} {'slot util':>10}")
        for name in ("wave", "continuous"):
            r = results[name]
            util = "-" if r["slot_util"] is None else f"{r['slot_util']:.2f}"
            print(f"{name:<12} {r['requests']:>4} {r['tokens']:>7} "
                  f"{r['wall_s']:>8.2f} {r['tok_per_s']:>8.1f} {util:>10}")
        print(f"\ncontinuous/wave tokens-per-sec speedup: {speedup:.2f}x "
              f"({'PASS' if speedup >= 1.2 else 'FAIL'} >= 1.2x)")
    return results


def rows():
    """benchmarks.run driver hook: tokens/sec per engine + the speedup."""
    r = run(verbose=False)
    for name in ("wave", "continuous"):
        d = r[name]
        us = d["wall_s"] / max(d["decode_steps"], 1) * 1e6
        yield (f"serving/{name}", us,
               f"tok_s={d['tok_per_s']:.1f};requests={d['requests']};"
               f"slot_util={d['slot_util'] if d['slot_util'] is not None else '-'}")
    yield ("serving/speedup", 0.0,
           f"continuous_over_wave={r['speedup']:.2f}x")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(args.requests, args.slots, args.max_seq, args.seed)


if __name__ == "__main__":
    main()
