"""Tail latency under an arrival process: p50/p95/p99 TTFT and
inter-token latency plus goodput vs offered load, across dense/paged
engines and pipeline_k settings.

The sweep runs the REAL engine under the seeded workload driver on a
virtual clock (serving.workload): one scheduler step costs one virtual
time unit, arrivals follow a Poisson process, and every generated token
is timestamped through the engine's ``token_sink`` hook.  TTFT counts
from the request's ARRIVAL, so queueing delay shows up in the tail —
the p99 blows up as the offered load crosses the engine's service
capacity (~n_slots / mean_output_len requests per step), which is the
paper-regime the controller's arrival-rate signal exists for.  All
latency metrics are in scheduler steps: deterministic given the seed,
so CI gates the percentiles at the STRICT tolerance (run.py treats
``p50_/p95_/p99_``-prefixed metrics as lower-is-better).

Inter-token latency in this clock model equals the in-flight depth
(``pipeline_k`` steps per token for an occupied group) — the sweep's
``paged_k2`` rows document that pipelining trades per-request ITL for
admission headroom.

One wall-clock row (``load/async``) drives the same mid-load workload
through the AsyncServingEngine and asserts its per-request streams are
bit-identical to the virtual-clock run — the async front end may change
WHEN tokens are computed, never WHAT they are.

``SERVING_LOAD_SWEEP=wide`` (the label-gated CI job) widens the sweep:
longer horizon, an extra load point, and the bursty/diurnal arrival
processes.  Wide rows are for the uploaded artifact, not the committed
baseline — run them without ``--check``.

    PYTHONPATH=src python benchmarks/serving_load.py
"""
from __future__ import annotations

import asyncio
import os
import time

from benchmarks.serving_throughput import default_cfg
from repro.serving.async_runtime import AsyncServingEngine
from repro.serving.engine import ServingEngine
from repro.serving.workload import drive_virtual, make_workload, offered_load

MAX_SEQ = 64
PAGE_SIZE = 8
N_SLOTS = 4
LOADS = (0.10, 0.25, 0.45)       # requests per scheduler step
MID = 0.25                       # cross-setting comparison point
SEED = 11

PAGED = dict(paged=True, page_size=PAGE_SIZE)
PAGED_K2 = dict(paged=True, page_size=PAGE_SIZE, pipeline_k=2)


def _engine(cfg, **kw):
    return ServingEngine(cfg, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                         lam=10 ** 9, seed=0, **kw)


def _plan(wide: bool):
    """(row_name, engine_kwargs, process, rate) sweep points."""
    plan = [(f"load/paged/r{r:g}", PAGED, "poisson", r) for r in LOADS]
    plan += [(f"load/dense/r{MID:g}", {}, "poisson", MID),
             (f"load/paged_k2/r{MID:g}", PAGED_K2, "poisson", MID)]
    if wide:
        plan += [(f"load/dense/r{r:g}", {}, "poisson", r)
                 for r in LOADS if r != MID]
        plan += [("load/paged/r0.6", PAGED, "poisson", 0.6),
                 (f"load/paged/bursty_r{MID:g}", PAGED, "bursty", MID),
                 (f"load/paged/diurnal_r{MID:g}", PAGED, "diurnal", MID)]
    return plan


async def _drive_async(eng, reqs):
    """All requests submitted up front in arrival order (same admission
    order as the virtual-clock driver), streamed to completion."""
    rt = AsyncServingEngine(eng, queue_limit=len(reqs) + 1)
    async with rt:
        handles = [rt.submit(r.prompt, max_new_tokens=r.max_new_tokens)
                   for r in sorted(reqs, key=lambda r: r.t_arrival)]
        await rt.drain()
    return {h.rid: list(h.tokens) for h in handles}


def run(verbose: bool = True, wide: bool = False) -> dict:
    cfg = default_cfg()
    horizon = 240.0 if wide else 120.0
    results = []
    streams_at_mid = {}
    for name, kw, proc, rate in _plan(wide):
        reqs = make_workload(proc, rate=rate, horizon=horizon, seed=SEED,
                             vocab=cfg.vocab_size)
        eng = _engine(cfg, **kw)
        t0 = time.monotonic()
        m = drive_virtual(eng, reqs)
        wall = time.monotonic() - t0
        if m["n_finished"] != len(reqs):
            raise RuntimeError(f"{name}: {m['n_finished']}/{len(reqs)} "
                               f"requests finished — the sweep must drain")
        off = offered_load(reqs, horizon)
        if proc == "poisson" and rate == MID:
            streams_at_mid[name] = m["streams"]
        results.append({"name": name, "metrics": m, "offered": off,
                        "wall_s": wall, "n_requests": len(reqs)})
    # dense and paged at the same load must stream the same tokens —
    # memory layout and async scheduling never change the math
    mid = [v for k, v in streams_at_mid.items()
           if k.startswith(("load/dense", "load/paged/"))]
    if len(mid) == 2 and mid[0] != mid[1]:
        raise RuntimeError("dense and paged streams diverged at equal "
                           "load — paging must be a pure re-layout")
    paged_mid = streams_at_mid.get(f"load/paged/r{MID:g}")
    reqs = make_workload("poisson", rate=MID, horizon=horizon, seed=SEED,
                         vocab=cfg.vocab_size)
    t0 = time.monotonic()
    async_streams = asyncio.run(_drive_async(_engine(cfg, **PAGED), reqs))
    async_wall = time.monotonic() - t0
    if paged_mid is not None and async_streams != paged_mid:
        raise RuntimeError("async per-request streams diverged from the "
                           "synchronous engine — the front end must be "
                           "scheduling-only")
    out = {"rows": results, "async": {
        "wall_s": async_wall, "requests": len(async_streams),
        "tokens": sum(len(t) for t in async_streams.values())}}
    if verbose:
        print(f"{'row':<26} {'req':>4} {'offered':>8} {'p50':>6} "
              f"{'p95':>6} {'p99':>6} {'p99itl':>7} {'goodput':>8}")
        for r in results:
            m = r["metrics"]
            print(f"{r['name']:<26} {r['n_requests']:>4} "
                  f"{r['offered']['req_rate']:>8.3f} "
                  f"{m['p50_ttft']:>6.1f} {m['p95_ttft']:>6.1f} "
                  f"{m['p99_ttft']:>6.1f} {m['p99_itl']:>7.2f} "
                  f"{m['goodput']:>8.3f}")
        a = out["async"]
        print(f"\nasync runtime: {a['requests']} requests, "
              f"{a['tokens']} tokens in {a['wall_s']:.2f}s wall — streams "
              f"bit-identical to the synchronous engine (asserted)")
    return out


def rows():
    """benchmarks.run driver hook.  Latency percentiles are virtual-clock
    deterministic -> gated strictly; us_per_call is wall -> loose gate."""
    wide = os.environ.get("SERVING_LOAD_SWEEP") == "wide"
    r = run(verbose=False, wide=wide)
    for row in r["rows"]:
        m, off = row["metrics"], row["offered"]
        us = row["wall_s"] / max(m["steps"], 1) * 1e6
        yield (row["name"], us,
               f"p50_ttft={m['p50_ttft']:.2f};p95_ttft={m['p95_ttft']:.2f};"
               f"p99_ttft={m['p99_ttft']:.2f};p50_itl={m['p50_itl']:.2f};"
               f"p95_itl={m['p95_itl']:.2f};p99_itl={m['p99_itl']:.2f};"
               f"goodput={m['goodput']:.3f};"
               f"offered_load={off['req_rate']:.3f}")
    a = r["async"]
    us = a["wall_s"] / max(a["tokens"], 1) * 1e6
    yield (f"load/async/r{MID:g}", us,
           f"requests={a['requests']};tokens={a['tokens']}")


if __name__ == "__main__":
    run(wide=os.environ.get("SERVING_LOAD_SWEEP") == "wide")
