"""§V.D(c) — scalability with increasing device count: latency and
controller wall-time as the network grows (coordination overhead), on both
the single-layer column model and the per-layer block graph
(``n_layers > 1`` axis: the controller now places n_layers·(h+2) blocks,
so per-interval wall-time measures the per-layer coordination cost)."""
from __future__ import annotations

import time


from benchmarks.paper_setup import paper_blocks, paper_cost, policy_kwargs
from repro.core import ALL_POLICIES, DeviceNetwork, make_blocks, simulate
from repro.core.blocks import CostModel
from repro.core.network import GB

DEVICE_COUNTS = (5, 10, 25, 40)
N_TOKENS = 200

# per-layer graph axis: smaller horizon — the controller does n_layers x
# the work per interval, and the wall-time trend is the datum
LAYER_COUNTS = (1, 4, 8)
GRAPH_DEVICES = 10
GRAPH_N_TOKENS = 60
GRAPH_HEADS = 8


def run(seed: int = 7):
    blocks = paper_blocks()
    cost = paper_cost()
    out = {}
    for nd in DEVICE_COUNTS:
        net = DeviceNetwork.sample(nd, seed=seed,
                                   mem_range=(2 * GB, 8 * GB))
        pol = ALL_POLICIES["resource-aware"](blocks, cost,
                                             **policy_kwargs("resource-aware"))
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, N_TOKENS, seed=11)
        out[nd] = dict(total=res.total_latency,
                       controller_ms=(time.time() - t0) / N_TOKENS * 1e3,
                       migrations=res.migrations)
    return out


def run_graph(seed: int = 7):
    """Controller cost vs model depth: n_layers·(h+2) blocks per interval."""
    out = {}
    for nl in LAYER_COUNTS:
        blocks = make_blocks(GRAPH_HEADS, nl)
        cost = CostModel(d_model=2048, n_heads=GRAPH_HEADS, L0=64,
                         n_layers=nl, compute_mode="incremental",
                         layer_mode="graph")
        net = DeviceNetwork.sample(GRAPH_DEVICES, seed=seed,
                                   mem_range=(2 * GB, 8 * GB))
        pol = ALL_POLICIES["resource-aware"](blocks, cost, deadline=0.2)
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, GRAPH_N_TOKENS, seed=11)
        out[nl] = dict(total=res.total_latency,
                       n_blocks=len(blocks),
                       controller_ms=(time.time() - t0) / GRAPH_N_TOKENS * 1e3,
                       migrations=res.migrations)
    return out


def rows():
    out = run()
    for nd, d in out.items():
        yield (f"scalability/devices={nd}", d["controller_ms"] * 1e3,
               f"total_s={d['total']:.1f};migr={d['migrations']}")
    out = run_graph()
    for nl, d in out.items():
        yield (f"scalability/layers={nl}", d["controller_ms"] * 1e3,
               f"total_s={d['total']:.1f};blocks={d['n_blocks']};"
               f"migr={d['migrations']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
