"""§V.D(c) — scalability with increasing device count: latency and
controller wall-time as the network grows (coordination overhead)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import paper_blocks, paper_cost, policy_kwargs
from repro.core import ALL_POLICIES, DeviceNetwork, simulate
from repro.core.network import GB

DEVICE_COUNTS = (5, 10, 25, 40)
N_TOKENS = 200


def run(seed: int = 7):
    blocks = paper_blocks()
    cost = paper_cost()
    out = {}
    for nd in DEVICE_COUNTS:
        net = DeviceNetwork.sample(nd, seed=seed,
                                   mem_range=(2 * GB, 8 * GB))
        pol = ALL_POLICIES["resource-aware"](blocks, cost,
                                             **policy_kwargs("resource-aware"))
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, N_TOKENS, seed=11)
        out[nd] = dict(total=res.total_latency,
                       controller_ms=(time.time() - t0) / N_TOKENS * 1e3,
                       migrations=res.migrations)
    return out


def rows():
    out = run()
    for nd, d in out.items():
        yield (f"scalability/devices={nd}", d["controller_ms"] * 1e3,
               f"total_s={d['total']:.1f};migr={d['migrations']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
