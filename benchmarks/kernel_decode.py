"""Benchmark group ``kernel_decode``: the placement-driven resident-slice
flash-decode grid vs padded-to-global-H dispatch on a skewed per-layer
placement (implementation in kernel_bench.bench_kernel_decode; registered
separately so CI's fast profile can run it without the full kernel
sweeps)."""
from benchmarks.kernel_bench import kernel_decode_rows as rows

if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
