"""Cross-device decode pipelining: simulated tokens/sec with K tokens in
flight vs the sequential path, on the fig3/layered topology (8-layer
per-layer block graph, 8 devices, heterogeneous 0.05-2 Gbps links).

Acceptance: >= 1.3x simulated tokens/sec over sequential decode at the
default depth.  Sequential decode walks one token through the layers
back-to-back, idling every device that hosts other layers; with per-layer
placements, K different requests' tokens can occupy layer-disjoint stages
concurrently (Model-Distributed Inference style micro-batching), so the
steady-state interval is the bottleneck *resource* time, not the critical
path (``delay.pipelined_inference_delay``).

Also exercised: the pipeline-aware ResourceAwarePolicy objective
(D_pipe + D_mig), the stage-partition view, and a small continuous-
batching engine run with ``pipeline_k`` slot groups (scheduler smoke: the
in-flight engine must produce the same streams as the sequential one).

    PYTHONPATH=src python -m benchmarks.pipelined_decode
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import (LAYERED_DEADLINE, layered_blocks,
                                    layered_cost, layered_net)
from repro.core import ALL_POLICIES, simulate
from repro.core.placement_bridge import stage_slot_partition

K_DEPTHS = (2, 4, 8)
N_TOKENS = 120


def run(n_tokens: int = N_TOKENS, seed: int = 0, sim_seed: int = 100):
    """Simulated decode throughput, sequential vs K in flight."""
    blocks = layered_blocks()
    cost = layered_cost()
    out = {}
    t0 = time.time()
    pol = ALL_POLICIES["resource-aware"](blocks, cost,
                                         deadline=LAYERED_DEADLINE)
    res = simulate(pol, blocks, cost, layered_net(seed=seed,
                                                  horizon_tau=n_tokens + 50),
                   n_tokens, seed=sim_seed, fluctuate=False)
    out["sequential"] = dict(total=res.total_latency,
                             tok_s=n_tokens / res.total_latency,
                             wall=time.time() - t0, stages=None)
    for k in K_DEPTHS:
        t0 = time.time()
        net = layered_net(seed=seed, horizon_tau=n_tokens + 50)
        pol = ALL_POLICIES["resource-aware"](blocks, cost,
                                             deadline=LAYERED_DEADLINE,
                                             pipeline_k=k)
        res = simulate(pol, blocks, cost, net, n_tokens, seed=sim_seed,
                       fluctuate=False, pipeline_k=k)
        place = pol.place(net.copy(), n_tokens, None)
        stages = 0 if place is None else \
            len(stage_slot_partition(place, blocks, net.n_devices))
        out[f"K={k}"] = dict(total=res.total_latency,
                             tok_s=n_tokens / res.total_latency,
                             wall=time.time() - t0, stages=stages)
    return out


def run_engine(seed: int = 0) -> dict:
    """Continuous-batching engine with pipeline_k slot groups: the
    in-flight scheduler must reproduce the sequential streams bit-for-bit
    and fire controller intervals every lam*K steps."""
    from repro.configs import get_config
    from repro.serving.engine import ServingEngine

    cfg = get_config("llama3-8b").with_overrides(
        n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4,
        d_head=16, vocab_size=97, dtype="float32", param_dtype="float32")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 97, size=n) for n in (4, 9, 6, 11)]

    def drive(k, lam):
        eng = ServingEngine(cfg, n_slots=4, max_seq=48, lam=lam, seed=seed,
                            pipeline_k=k)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        t0 = time.monotonic()
        eng.run()
        wall = time.monotonic() - t0
        toks = sum(len(r.out_tokens) for r in eng.finished)
        return ({r.rid: r.out_tokens for r in eng.finished}, toks, wall,
                eng.migration_log)

    seq, toks, _, _ = drive(1, 10 ** 9)
    pipe, ptoks, wall, mlog = drive(2, 4)
    return {"streams_equal": seq == pipe, "tokens": ptoks, "wall_s": wall,
            "interval_steps": [e["step"] for e in mlog],
            "cadence_ok": all(e["step"] % 8 == 0 for e in mlog)}


def rows():
    out = run()
    seq = out["sequential"]["tok_s"]
    for name, d in out.items():
        speedup = d["tok_s"] / seq
        stages = "" if d["stages"] is None else f";stages={d['stages']}"
        yield (f"pipelined/{name}", d["wall"] * 1e6,
               f"tok_s={d['tok_s']:.2f};x_seq={speedup:.2f}{stages}")
    e = run_engine()
    # x_streams_equal carries the gate (1.0 iff the pipelined stream is
    # bit-identical to sequential): the roundtrip wall is jit-compile
    # dominated and drifts with machine state, so it must not gate.
    yield ("pipelined/engine_k2", e["wall_s"] * 1e6,
           f"x_streams_equal={float(e['streams_equal']):.1f};"
           f"tokens={e['tokens']};cadence_ok={e['cadence_ok']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
