"""Benchmark driver — one module per paper table/figure (+ roofline,
kernel micro-benches, and the serving-engine throughput comparison).
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only small_scale,fig3,...]
                                          [--json DIR]
                                          [--check BASELINE_DIR]

``--json DIR`` additionally writes each group's rows to
``DIR/BENCH_<group>.json`` as ``[{"name", "us_per_call", "derived"}, ...]``
— the machine-readable perf trajectory.

``--check BASELINE_DIR`` is the regression gate: every group that just
ran is compared against ``BASELINE_DIR/BENCH_<group>.json``.  A row fails
when its latency (``us_per_call``, lower is better) regresses by more
than ``--check-tol`` (default 15%), or a throughput-like derived metric
(``tok_s`` / ``x_*`` / ``speedup`` / ``goodput``, higher is better), a
quality ratio (``ratio_to_exact``, lower is better), or a latency
percentile (``p50_``/``p95_``/``p99_``-prefixed, lower is better —
virtual-clock deterministic, so gated at the strict tolerance, with the
failure message naming the row's offered load) regresses by the same
margin; improvements always pass.  The row SETS must match exactly, both ways:
baseline rows missing from the fresh run fail (coverage loss), and fresh
rows absent from the baseline fail too — an unmatched new row would
otherwise run ungated forever, silently passing whatever it measures.
Refresh the committed baselines with ``--json benchmarks/baselines
--only <groups>`` on the CI reference machine.
"""
import argparse
import json
import os
import sys
import traceback

MODULES = [
    ("small_scale", "benchmarks.small_scale"),          # §V.C table
    ("fig3", "benchmarks.latency_vs_tokens"),           # Fig. 3 (+ layered)
    ("fig4", "benchmarks.memory_vs_tokens"),            # Fig. 4
    ("scalability", "benchmarks.scalability"),          # §V.D(c) (+ layers)
    ("serving_throughput", "benchmarks.serving_throughput"),  # engine tok/s
    ("paged_serving", "benchmarks.paged_serving"),      # paged KV capacity
    ("pipelined", "benchmarks.pipelined_decode"),       # K-in-flight tok/s
    ("pipeline_search", "benchmarks.pipeline_search"),  # bottleneck search
    ("kernels", "benchmarks.kernel_bench"),             # per-kernel
    ("kernel_decode", "benchmarks.kernel_decode"),      # resident vs padded
    ("moe_serving", "benchmarks.moe_serving"),          # expert-aware place
    ("serving_load", "benchmarks.serving_load"),        # tail latency vs load
    ("elastic_serving", "benchmarks.elastic_serving"),  # device churn
    ("roofline", "benchmarks.roofline"),                # deliverable (g)
]

# derived-metric directions for --check: key PREFIX -> True when higher is
# better (prefix, not substring, so e.g. a future max_err/idx_miss cannot
# be misclassified).  Unlisted keys (roofline bytes, grid_rows, ...) are
# not gated.
HIGHER_BETTER = ("tok_s", "x_", "speedup", "goodput")
LOWER_BETTER = ("ratio_to_exact",)
# Latency percentiles (p50_ttft, p95_itl, ...): lower is better, and the
# serving_load sweep computes them on a VIRTUAL clock (scheduler steps,
# not wall seconds), so they are machine-independent and gate at the
# STRICT tolerance.  Never emit wall-clock percentiles under these
# prefixes — they would inherit the strict gate.
PCTL_LOWER = ("p50_", "p95_", "p99_")
# Derived metrics that are RATIOS OF WALL TIMES from one run (e.g. the
# kernel_decode resident-vs-padded speedup): same-machine, but the part
# above the structural work ratio is interpreter/overhead-sensitive, so
# they get the wall tolerance, not the strict deterministic one.
WALL_RATIO = ("x_padded",)


def parse_derived(derived: str) -> dict:
    out = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        k, _, val = part.partition("=")
        try:
            out[k] = float(val)
        except ValueError:
            continue
    return out


def _gated_metrics(row: dict):
    """(metric name, value, higher_is_better) for every gated metric.

    Rows that expose a deterministic derived metric are gated on THAT
    (it is the row's actual claim — e.g. the pipelined group's tok_s and
    small_scale's ratio_to_exact are machine-independent while their wall
    times are whole-benchmark noise and CI-runner speed); only rows
    without one are gated on raw us_per_call, which is meaningful when
    the baseline came from the same class of machine (refresh with
    --json on the CI reference runner; widen with --check-tol /
    BENCH_CHECK_TOL elsewhere)."""
    derived = parse_derived(row.get("derived", ""))
    gated = [(k, v, True) for k, v in derived.items()
             if k.startswith(HIGHER_BETTER)]
    gated += [(k, v, False) for k, v in derived.items() if k in LOWER_BETTER]
    gated += [(k, v, False) for k, v in derived.items()
              if k.startswith(PCTL_LOWER)]
    if not gated:
        gated = [("us_per_call", float(row["us_per_call"]), False)]
    yield from gated


def check_group(key: str, fresh_rows: list, baseline_dir: str,
                tol: float, wall_tol: float) -> list:
    """Compare one group's fresh rows to the committed baseline; returns a
    list of human-readable failure strings (empty = pass).

    ``tol`` gates the deterministic derived metrics; ``wall_tol`` gates
    raw us_per_call (wall-clock) rows and the WALL_RATIO derived metrics,
    which are only comparable within one machine class — CI on shared
    runners widens it via BENCH_CHECK_TOL_WALL.  A baseline-gated metric that disappears from
    the fresh row is a failure, not a skip: silently falling back to a
    different metric would let a regression hide behind a rename."""
    path = os.path.join(baseline_dir, f"BENCH_{key}.json")
    if not os.path.exists(path):
        return [f"{key}: no baseline at {path} (commit one with "
                f"--json {baseline_dir})"]
    with open(path) as f:
        baseline = json.load(f)
    fresh = {r["name"]: r for r in fresh_rows}
    fails = []
    # fail-closed on NEW row names: a fresh row with no baseline row has
    # no gate at all — it used to pass silently (a renamed row even read
    # as "missing baseline" on one side and nothing on the other), so any
    # unmatched rows fail until the baseline is refreshed to cover them
    known = {r["name"] for r in baseline}
    unmatched = [n for n in fresh if n not in known]
    if unmatched:
        fails.append(f"{key}: {len(unmatched)} row(s) not in the baseline "
                     f"(ungated): {', '.join(sorted(unmatched))} — refresh "
                     f"with --json {baseline_dir} --only {key}")
    for brow in baseline:
        name = brow["name"]
        frow = fresh.get(name)
        if frow is None:
            fails.append(f"{name}: present in baseline, missing from this "
                         f"run (coverage loss)")
            continue
        fm = {k: v for k, v, _ in _gated_metrics(frow)}
        # us_per_call is always present on the fresh row even when a
        # newly added derived metric stops _gated_metrics from falling
        # back to it — a pure coverage improvement must not read as
        # "vanished".
        fm.setdefault("us_per_call", float(frow["us_per_call"]))
        for metric, base_val, higher in _gated_metrics(brow):
            if metric not in fm:
                fails.append(f"{name}: gated metric {metric} vanished "
                             f"from this run (was {base_val:.3g})")
                continue
            if base_val == 0:
                continue
            t = wall_tol if metric == "us_per_call" \
                or metric in WALL_RATIO else tol
            val = fm[metric]
            # tail-latency regressions are only interpretable next to the
            # load that produced them — print the row's offered load
            ctx = ""
            if metric.startswith(PCTL_LOWER):
                off = parse_derived(frow.get("derived", "")) \
                    .get("offered_load")
                if off is not None:
                    ctx = f" [at offered_load={off:.3g} req/step]"
            if higher and val < base_val * (1 - t):
                fails.append(f"{name}: {metric} {val:.3g} < baseline "
                             f"{base_val:.3g} - {t:.0%}{ctx}")
            elif not higher and val > base_val * (1 + t):
                fails.append(f"{name}: {metric} {val:.3g} > baseline "
                             f"{base_val:.3g} + {t:.0%}{ctx}")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark groups")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="directory to write BENCH_<group>.json files")
    ap.add_argument("--check", default=None, metavar="BASELINE_DIR",
                    help="fail when a just-run group regresses vs the "
                         "committed BENCH_<group>.json baselines")
    ap.add_argument("--check-tol", type=float,
                    default=float(os.environ.get("BENCH_CHECK_TOL", 0.15)),
                    help="relative regression tolerance for --check "
                         "(default 0.15 = 15%%; env BENCH_CHECK_TOL)")
    env_wall = os.environ.get("BENCH_CHECK_TOL_WALL")
    ap.add_argument("--check-tol-wall", type=float,
                    default=float(env_wall) if env_wall is not None else None,
                    help="tolerance for raw wall-clock (us_per_call) rows; "
                         "defaults to --check-tol — widen on machines that "
                         "differ from the baseline recorder (env "
                         "BENCH_CHECK_TOL_WALL); 0 means exact")
    args = ap.parse_args()
    wall_tol = args.check_tol if args.check_tol_wall is None \
        else args.check_tol_wall
    only = set(args.only.split(",")) if args.only else None
    json_dir = args.json
    print("name,us_per_call,derived")
    failed = []
    check_fails = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        group_rows = []
        group_ok = True
        try:
            mod = __import__(modname, fromlist=["rows"])
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}", flush=True)
                group_rows.append({"name": name, "us_per_call": us,
                                   "derived": derived})
        except Exception as e:  # noqa: BLE001 — report, keep benching
            failed.append((key, e))
            group_ok = False    # never record a truncated group as clean
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        # check BEFORE any --json write: with --json and --check aimed at
        # the same directory the gate must compare against the OLD
        # baseline, not the file we are about to refresh (comparing fresh
        # rows to themselves would pass vacuously).
        if args.check and group_ok:
            check_fails.extend(check_group(key, group_rows, args.check,
                                           args.check_tol, wall_tol))
        if json_dir and group_rows and group_ok:
            os.makedirs(json_dir, exist_ok=True)
            path = os.path.join(json_dir, f"BENCH_{key}.json")
            with open(path, "w") as f:
                json.dump(group_rows, f, indent=1)
    if check_fails:
        print(f"[check] {len(check_fails)} regression(s) vs "
              f"{args.check}:", file=sys.stderr)
        for msg in check_fails:
            print(f"[check]   {msg}", file=sys.stderr)
    if failed or check_fails:
        sys.exit(1)


if __name__ == '__main__':
    main()
