"""Benchmark driver — one module per paper table/figure (+ roofline,
kernel micro-benches, and the serving-engine throughput comparison).
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only small_scale,fig3,...]
                                          [--json DIR]

``--json DIR`` additionally writes each group's rows to
``DIR/BENCH_<group>.json`` as ``[{"name", "us_per_call", "derived"}, ...]``
— the machine-readable perf trajectory.
"""
import argparse
import json
import os
import sys
import traceback

MODULES = [
    ("small_scale", "benchmarks.small_scale"),          # §V.C table
    ("fig3", "benchmarks.latency_vs_tokens"),           # Fig. 3 (+ layered)
    ("fig4", "benchmarks.memory_vs_tokens"),            # Fig. 4
    ("scalability", "benchmarks.scalability"),          # §V.D(c) (+ layers)
    ("serving_throughput", "benchmarks.serving_throughput"),  # engine tok/s
    ("pipelined", "benchmarks.pipelined_decode"),       # K-in-flight tok/s
    ("kernels", "benchmarks.kernel_bench"),             # per-kernel
    ("roofline", "benchmarks.roofline"),                # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark groups")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="directory to write BENCH_<group>.json files")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        group_rows = []
        group_ok = True
        try:
            mod = __import__(modname, fromlist=["rows"])
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}", flush=True)
                group_rows.append({"name": name, "us_per_call": us,
                                   "derived": derived})
        except Exception as e:  # noqa: BLE001 — report, keep benching
            failed.append((key, e))
            group_ok = False    # never record a truncated group as clean
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        if args.json and group_rows and group_ok:
            os.makedirs(args.json, exist_ok=True)
            path = os.path.join(args.json, f"BENCH_{key}.json")
            with open(path, "w") as f:
                json.dump(group_rows, f, indent=1)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
