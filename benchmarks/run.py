"""Benchmark driver — one module per paper table/figure (+ roofline and
kernel micro-benches). Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only small_scale,fig3,...]
"""
import argparse
import sys
import traceback

MODULES = [
    ("small_scale", "benchmarks.small_scale"),          # §V.C table
    ("fig3", "benchmarks.latency_vs_tokens"),           # Fig. 3
    ("fig4", "benchmarks.memory_vs_tokens"),            # Fig. 4
    ("scalability", "benchmarks.scalability"),          # §V.D(c)
    ("kernels", "benchmarks.kernel_bench"),             # per-kernel
    ("roofline", "benchmarks.roofline"),                # deliverable (g)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark groups")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            mod = __import__(modname, fromlist=["rows"])
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001 — report, keep benching
            failed.append((key, e))
            print(f"{key}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
