"""Fig. 4 — memory usage vs generated-token step (25 devices): total
footprint, max single-device usage, and overflow-above-capacity (the
quantity the paper's 'memory mitigation' claim is about)."""
from __future__ import annotations

import time


from benchmarks.paper_setup import (medium_net, paper_blocks, paper_cost,
                                    policy_kwargs)
from repro.core import ALL_POLICIES, simulate

POLICIES = ("resource-aware", "edgeshard", "galaxy")
N_TOKENS = 1000
CHECKPOINTS = (100, 500, 1000)


def run(n_tokens: int = N_TOKENS, seed: int = 11):
    blocks = paper_blocks()
    cost = paper_cost()
    net = medium_net(tight=True)
    out = {}
    for name in POLICIES:
        pol = ALL_POLICIES[name](blocks, cost, **policy_kwargs(name))
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, n_tokens, seed=seed)
        out[name] = dict(
            total_gb={n: res.mem_total_series[n - 1] / 2 ** 30
                      for n in CHECKPOINTS},
            max_gb={n: res.mem_max_series[n - 1] / 2 ** 30
                    for n in CHECKPOINTS},
            stall_s=float(sum(s.d_overload for s in res.steps)),
            wall=time.time() - t0)
    return out


def rows():
    out = run()
    for name, d in out.items():
        yield (f"fig4/{name}", d["wall"] * 1e6,
               f"mem_max@1000={d['max_gb'][1000]:.2f}GB;"
               f"mem_total@1000={d['total_gb'][1000]:.2f}GB;"
               f"overload_stall={d['stall_s']:.1f}s")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
