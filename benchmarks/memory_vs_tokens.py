"""Fig. 4 — memory usage vs generated-token step (25 devices): total
footprint, max single-device usage, and overflow-above-capacity (the
quantity the paper's 'memory mitigation' claim is about).

The ``serving`` section measures the REAL engine instead of the cost
model: KV bytes actually allocated (live pages) versus the dense
engine's reserved worst case (``n_slots x max_seq`` rows, paid up front
for the life of every request) — the paper's memory curves claim bytes
that grow with generated tokens, which only the paged engine delivers."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import (medium_net, paper_blocks, paper_cost,
                                    policy_kwargs)
from repro.core import ALL_POLICIES, simulate

POLICIES = ("resource-aware", "edgeshard", "galaxy")
N_TOKENS = 1000
CHECKPOINTS = (100, 500, 1000)


def run(n_tokens: int = N_TOKENS, seed: int = 11):
    blocks = paper_blocks()
    cost = paper_cost()
    net = medium_net(tight=True)
    out = {}
    for name in POLICIES:
        pol = ALL_POLICIES[name](blocks, cost, **policy_kwargs(name))
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, n_tokens, seed=seed)
        out[name] = dict(
            total_gb={n: res.mem_total_series[n - 1] / 2 ** 30
                      for n in CHECKPOINTS},
            max_gb={n: res.mem_max_series[n - 1] / 2 ** 30
                    for n in CHECKPOINTS},
            stall_s=float(sum(s.d_overload for s in res.steps)),
            wall=time.time() - t0)
    return out


def serving_live_bytes(n_requests: int = 8, seed: int = 0) -> dict:
    """Live (allocated-page) KV bytes on the paged engine vs the dense
    engine's reserved bytes, sampled per decode step."""
    from benchmarks.serving_throughput import default_cfg
    from repro.serving.engine import ServingEngine

    cfg = default_cfg()
    eng = ServingEngine(cfg, n_slots=4, max_seq=64, lam=10 ** 9,
                        seed=seed, paged=True, page_size=8)
    k = eng.states[0]["cache"]["k"]
    # bytes one token-row of k+v costs across the layer stack
    row_bytes = 2 * int(k.shape[0]) * int(k.shape[3]) * int(k.shape[4]) \
        * int(np.dtype(k.dtype).itemsize)
    rng = np.random.default_rng(seed)
    for i in range(n_requests):
        eng.submit(rng.integers(0, 97, size=4 + 2 * (i % 5)),
                   max_new_tokens=8)
    live = []
    t0 = time.time()
    while eng.step():
        live.append(sum(a.live_pages for a in eng.allocators)
                    * eng.page_size * row_bytes)
    reserved = eng.n_slots * eng.max_seq * row_bytes   # dense, constant
    return {"live_peak": max(live), "live_mean": float(np.mean(live)),
            "reserved": reserved, "wall": time.time() - t0}


def rows():
    out = run()
    for name, d in out.items():
        yield (f"fig4/{name}", d["wall"] * 1e6,
               f"mem_max@1000={d['max_gb'][1000]:.2f}GB;"
               f"mem_total@1000={d['total_gb'][1000]:.2f}GB;"
               f"overload_stall={d['stall_s']:.1f}s")
    s = serving_live_bytes()
    yield ("fig4/serving_live_bytes", s["wall"] * 1e6,
           f"live_peak_kb={s['live_peak'] / 1024:.1f};"
           f"live_mean_kb={s['live_mean'] / 1024:.1f};"
           f"reserved_dense_kb={s['reserved'] / 1024:.1f}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
