"""Fig. 3 — inference latency vs generated-token step on 25 devices,
resource-aware vs EdgeShard vs Galaxy (plus static ablation), in the
paper's 2-8 GB regime and the tight-memory overload regime."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import (medium_net, paper_blocks, paper_cost,
                                    policy_kwargs)
from repro.core import ALL_POLICIES, simulate

POLICIES = ("resource-aware", "lookahead", "edgeshard", "galaxy", "static")
N_TOKENS = 1000   # the paper's horizon


def run(tight: bool, n_tokens: int = N_TOKENS, seed: int = 11):
    blocks = paper_blocks()
    cost = paper_cost()
    net = medium_net(tight=tight)
    out = {}
    for name in POLICIES:
        kw = dict(policy_kwargs(name))
        if name == "lookahead":
            kw["deadline"] = 0.2
        pol = ALL_POLICIES[name](blocks, cost, **kw)
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, n_tokens, seed=seed)
        out[name] = dict(total=res.total_latency,
                         per_step_last=float(res.per_step_latency[-1]),
                         migrations=res.migrations,
                         series=res.per_step_latency,
                         cumulative=[s.cumulative for s in res.steps],
                         wall=time.time() - t0)
    return out


def rows():
    for tight in (False, True):
        regime = "tight" if tight else "paper"
        out = run(tight)
        ra = out["resource-aware"]["total"]
        for name, d in out.items():
            speedup = d["total"] / ra
            yield (f"fig3/{regime}/{name}", d["wall"] * 1e6,
                   f"total_s={d['total']:.1f};xRA={speedup:.2f};"
                   f"migr={d['migrations']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
