"""Fig. 3 — inference latency vs generated-token step on 25 devices,
resource-aware vs EdgeShard vs Galaxy (plus static ablation), in the
paper's 2-8 GB regime and the tight-memory overload regime.

The ``layered`` scenario is the n_layers>1 axis: an 8-layer per-layer
block graph on a heterogeneous-bandwidth 8-device cluster whose per-device
memory fits about one decoder layer.  The headline comparison is per-layer
head placement (resource-aware on the graph) vs the old column
co-partitioning (``column-copartition``) under the SAME per-layer delay
model — per-layer placement must come out strictly faster: column blocks
are n_layers× chunkier, so they wedge against the per-device capacities as
the KV caches grow and pay overload stalls, while per-layer blocks keep
fitting and adapt placement layer-by-layer."""
from __future__ import annotations

import time


from benchmarks.paper_setup import (LAYERED_DEADLINE, layered_blocks,
                                    layered_cost, layered_net, medium_net,
                                    paper_blocks, paper_cost, policy_kwargs)
from repro.core import ALL_POLICIES, simulate

POLICIES = ("resource-aware", "lookahead", "edgeshard", "galaxy", "static")
N_TOKENS = 1000   # the paper's horizon

LAYERED_POLICIES = ("resource-aware", "column-copartition", "edgeshard",
                    "galaxy")
LAYERED_N_TOKENS = 150


def run(tight: bool, n_tokens: int = N_TOKENS, seed: int = 11):
    blocks = paper_blocks()
    cost = paper_cost()
    net = medium_net(tight=tight)
    out = {}
    for name in POLICIES:
        kw = dict(policy_kwargs(name))
        if name == "lookahead":
            kw["deadline"] = 0.2
        pol = ALL_POLICIES[name](blocks, cost, **kw)
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, n_tokens, seed=seed)
        out[name] = dict(total=res.total_latency,
                         per_step_last=float(res.per_step_latency[-1]),
                         migrations=res.migrations,
                         series=res.per_step_latency,
                         cumulative=[s.cumulative for s in res.steps],
                         wall=time.time() - t0)
    return out


def run_layered(n_tokens: int = LAYERED_N_TOKENS, seed: int = 0,
                sim_seed: int = 100):
    """Per-layer graph vs column co-partitioning on the heterogeneous-
    bandwidth edge cluster (all policies priced by the per-layer delay
    model)."""
    blocks = layered_blocks()
    cost = layered_cost()
    net = layered_net(seed=seed, horizon_tau=n_tokens + 50)
    out = {}
    for name in LAYERED_POLICIES:
        kw = dict(deadline=LAYERED_DEADLINE) \
            if name in ("resource-aware", "column-copartition") else {}
        pol = ALL_POLICIES[name](blocks, cost, **kw)
        t0 = time.time()
        res = simulate(pol, blocks, cost, net, n_tokens, seed=sim_seed,
                       fluctuate=False)
        out[name] = dict(total=res.total_latency,
                         stall=float(sum(s.d_overload for s in res.steps)),
                         infeasible=int(sum(s.infeasible for s in res.steps)),
                         migrations=res.migrations,
                         wall=time.time() - t0)
    return out


def rows():
    for tight in (False, True):
        regime = "tight" if tight else "paper"
        out = run(tight)
        ra = out["resource-aware"]["total"]
        for name, d in out.items():
            speedup = d["total"] / ra
            yield (f"fig3/{regime}/{name}", d["wall"] * 1e6,
                   f"total_s={d['total']:.1f};xRA={speedup:.2f};"
                   f"migr={d['migrations']}")
    out = run_layered()
    ra = out["resource-aware"]["total"]
    for name, d in out.items():
        yield (f"fig3/layered/{name}", d["wall"] * 1e6,
               f"total_s={d['total']:.2f};xRA={d['total'] / ra:.2f};"
               f"stall_s={d['stall']:.2f};infeas={d['infeasible']};"
               f"migr={d['migrations']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
