"""Bottleneck-targeted pipeline placement search vs the PR-3 rescoring
policy: simulated decode throughput with K tokens in flight on the
fig3/layered topology (8-layer per-layer block graph, 8 devices,
heterogeneous 0.05-2 Gbps links) under the paper's fluctuating
background-load regime (§V.B "inject background tasks").

Acceptance: >= 1.3x simulated tokens/sec over the ``pipeline_k``-rescoring
``ResourceAwarePolicy`` at K=8.  The rescoring policy only *scores*
D_pipe after Algorithm-1 assignment, and its §III.G migration filter
demands a one-interval payback — so when a device's background load
spikes, the rescue migration never pays at λ=1 and the placement stays
wedged on the straggler (the bottleneck resource's busy time IS the
steady-state token interval).  ``BottleneckAwarePolicy`` searches: a
stage-balanced layer→device chain seed plus layer-chain moves aimed at
the argmax resource of ``resource_busy_times``, with migrations amortized
over ``amortize`` intervals — so the stream follows the compute.

Also exercised: the static-load control (same topology, no fluctuation —
the two policies should be near parity there; the win is adaptivity, not
a different cost model), the τ=1 single-shot search quality, and a small
continuous-batching engine run where a bottleneck-mode controller plan
physically migrates (streams must equal the migration-free run).

    PYTHONPATH=src python -m benchmarks.pipeline_search
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import (LAYERED_DEADLINE, layered_blocks,
                                    layered_cost, layered_net)
from repro.core import ALL_POLICIES, pipelined_inference_delay, simulate

K_DEPTHS = (2, 8)
K_HEADLINE = 8
N_TOKENS = 120


def run(n_tokens: int = N_TOKENS, seed: int = 0, sim_seed: int = 100,
        fluctuate: bool = True, k_depths=K_DEPTHS):
    """Simulated decode throughput, rescoring vs bottleneck-targeted."""
    blocks = layered_blocks()
    cost = layered_cost()
    out = {}
    for k in k_depths:
        for name in ("resource-aware", "bottleneck-aware"):
            t0 = time.time()
            net = layered_net(seed=seed, horizon_tau=n_tokens + 50)
            pol = ALL_POLICIES[name](blocks, cost,
                                     deadline=LAYERED_DEADLINE, pipeline_k=k)
            res = simulate(pol, blocks, cost, net, n_tokens, seed=sim_seed,
                           fluctuate=fluctuate, pipeline_k=k)
            out[(name, k)] = dict(
                total=res.total_latency,
                tok_s=n_tokens / res.total_latency,
                d_mig=float(sum(s.d_mig for s in res.steps)),
                migrations=res.migrations,
                bneck_last=float(res.bottleneck_series[-1]),
                wall=time.time() - t0)
    return out


def run_single_shot(seed: int = 0, tau: int = 1, k: int = K_HEADLINE):
    """τ=1 search quality: D_pipe(K) of the one-shot placement each mode
    returns on the same fresh network (no migration history) — the
    never-worse-than-rescoring guarantee, measured."""
    blocks = layered_blocks()
    cost = layered_cost()
    out = {}
    for name in ("resource-aware", "bottleneck-aware"):
        t0 = time.time()
        net = layered_net(seed=seed, horizon_tau=N_TOKENS + 50)
        pol = ALL_POLICIES[name](blocks, cost, deadline=LAYERED_DEADLINE,
                                 pipeline_k=k)
        place = pol.place(net, tau, None)
        out[name] = dict(d_pipe=pipelined_inference_delay(
            place, blocks, cost, net, tau, k=k), wall=time.time() - t0)
    return out


def run_engine(seed: int = 0) -> dict:
    """Continuous-batching engine with ``search="bottleneck"``: the
    controller's bottleneck-mode plans drive REAL cache+weight migrations
    (straggler injected mid-serve) and the streams must equal the
    migration-free sequential run."""
    from repro.configs import get_config
    from repro.core import DeviceNetwork
    from repro.serving.engine import ServingEngine

    cfg = get_config("llama3-8b").with_overrides(
        n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4,
        d_head=16, vocab_size=97, dtype="float32", param_dtype="float32")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 97, size=n) for n in (4, 9, 6, 11)]

    def drive(k, lam, search, straggle_at=None):
        eng = ServingEngine(cfg, n_slots=4, max_seq=48, lam=lam, seed=seed,
                            pipeline_k=k, search=search,
                            net=DeviceNetwork.sample(4, seed=seed + 1))
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        t0 = time.monotonic()
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                dev = int(eng.controller.head_counts().argmax())
                eng.net.inject_straggler(dev, slowdown=500.0)
            if not eng.step():
                break
        wall = time.monotonic() - t0
        return ({r.rid: r.out_tokens for r in eng.finished}, wall,
                eng.migration_log)

    seq, _, _ = drive(1, 10 ** 9, "rescoring")
    pipe, wall, mlog = drive(2, 3, "bottleneck", straggle_at=6)
    applied = [e for e in mlog if e["applied"] and e["n_migrations"]]
    return {"streams_equal": seq == pipe, "applied": len(applied),
            "wall_s": wall}


def rows():
    for regime, fluctuate in (("fluct", True), ("static", False)):
        k_depths = K_DEPTHS if fluctuate else (K_HEADLINE,)
        out = run(fluctuate=fluctuate, k_depths=k_depths)
        for k in k_depths:
            base = out[("resource-aware", k)]["tok_s"]
            for name in ("resource-aware", "bottleneck-aware"):
                d = out[(name, k)]
                extra = "" if name == "resource-aware" else \
                    f";x_rescoring={d['tok_s'] / base:.2f}"
                yield (f"pipeline_search/{regime}/{name}_K{k}",
                       d["wall"] * 1e6,
                       f"tok_s={d['tok_s']:.2f}{extra};"
                       f"migr={d['migrations']};d_mig_s={d['d_mig']:.3f}")
    shot = run_single_shot()
    base = shot["resource-aware"]["d_pipe"]
    bn = shot["bottleneck-aware"]
    yield ("pipeline_search/single_shot_K8",
           (shot["resource-aware"]["wall"] + bn["wall"]) * 1e6,
           f"x_dpipe={base / bn['d_pipe']:.3f};"
           f"dpipe_ms={bn['d_pipe'] * 1e3:.3f}")
    e = run_engine()
    # gate on the deterministic claim, not the compile-dominated wall
    yield ("pipeline_search/engine_bneck_k2", e["wall_s"] * 1e6,
           f"x_streams_equal={float(e['streams_equal']):.1f};"
           f"applied={e['applied']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
