"""§V.C — small-scale optimality gap: 3-5 devices, N=4 tokens, exact
solver vs resource-aware vs simple baselines.  Paper claim: resource-aware
within 15-20% of optimal; Greedy/Round-Robin 40-60% behind."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import paper_cost, policy_kwargs
from repro.core import ALL_POLICIES, DeviceNetwork, exact_myopic, total_delay
from repro.core.blocks import make_blocks
from repro.core.network import GB
from repro.core.simulator import overload_stall

POLICIES = ("resource-aware", "greedy", "round-robin", "static",
            "dynamic-layer")
SCENARIOS = [(3, 3), (4, 1), (5, 5), (3, 9), (4, 9), (5, 13)]
N_TOKENS = 4


def run(n_heads: int = 4):
    blocks = make_blocks(n_heads)
    cost = paper_cost(n_heads=n_heads)
    ratios = {p: [] for p in POLICIES}
    wall = {p: 0.0 for p in POLICIES}
    for nd, seed in SCENARIOS:
        net = DeviceNetwork.sample(nd, seed=seed,
                                   mem_range=(1 * GB, 4 * GB))
        prev_e = None
        tot_e = 0.0
        for tau in range(1, N_TOKENS + 1):
            pe, ve = exact_myopic(blocks, cost, net, tau, prev_e)
            tot_e += ve
            prev_e = pe
        for name in POLICIES:
            pol = ALL_POLICIES[name](blocks, cost, **policy_kwargs(name))
            prev = None
            tot = 0.0
            t0 = time.time()
            for tau in range(1, N_TOKENS + 1):
                p = pol.place(net, tau, prev)
                tot += total_delay(prev, p, blocks, cost, net, tau)
                tot += overload_stall(p, blocks, cost, net, tau)
                prev = p
            wall[name] += time.time() - t0
            ratios[name].append(tot / tot_e)
    return {name: (float(np.mean(r)), wall[name] / len(SCENARIOS) * 1e6)
            for name, r in ratios.items()}


def rows():
    out = run()
    for name, (ratio, us) in out.items():
        yield (f"small_scale/{name}", us, f"ratio_to_exact={ratio:.3f}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
