"""Benchmark group ``moe_serving``: expert-aware vs expert-oblivious
placement under a skewed router load (§III applied at expert granularity).

Topology: the fig3/layered edge cluster (8 devices, heterogeneous
0.05-2 Gbps links, per-device memory around one decoder layer) serving an
8-layer, 8-head decoder whose ffn is an 8-expert MoE.  The router load is
SKEWED — a hot expert carries half of each layer's tokens — and fed to
the cost model exactly as the serving engine feeds its router-load EWMA.

Arms:
 - expert-oblivious: the dense-cost policy places head/proj/ffn blocks
   (it cannot see experts), and each layer's whole expert set is lifted
   onto the layer's ffn device — the colocation every dense placement
   implies.  Its placements are then PRICED under the expert-level cost
   model (identical totals, so the comparison is placement quality, not
   bookkeeping).
 - expert-aware: the same policy family operating on the expert-level
   block graph directly, spreading expert rows by observed load.

Acceptance (CI-gated via x_oblivious): >= 1.3x simulated tok/s at the
headline depth.  Each skewed row also attributes its bottleneck: which
device/link bounds the pipelined rate (``bneck=devJ|linkJ-K``) and its
per-token busy time (``bneck_s``, ungated — attribution, not a claim).  Also exercised: the end-to-end engine roundtrip — a
reduced mixtral stream with a PHYSICALLY applied expert migration must
equal the migration-free run bit-for-bit.

    PYTHONPATH=src python -m benchmarks.moe_serving
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.paper_setup import LAYERED_DEADLINE, layered_cost
from repro.core import ALL_POLICIES, simulate
from repro.core.blocks import graph_of
from repro.core.delay import bottleneck_attribution

N_EXPERTS = 8
N_TOKENS = 30
K_HEADLINE = 4
# hot expert takes half of every layer's tokens; the rest spread evenly
SKEW = (0.5,) + (0.5 / (N_EXPERTS - 1),) * (N_EXPERTS - 1)


def skewed_cost(**over):
    cost = layered_cost(n_experts=N_EXPERTS, **over)
    return cost.with_expert_loads(tuple(SKEW for _ in range(cost.n_layers)))


def moe_net(seed: int = 0, n_devices: int = 8, horizon_tau: int = 200):
    """The layered edge cluster re-sized for expert weights: per-device
    memory around ONE MoE layer's footprint (expert weights dominate —
    3·D·F·b per expert row vs the dense layer's activation-coupled ffn
    term), same heterogeneous bandwidth/compute ranges."""
    from repro.core.network import GBPS, DeviceNetwork

    cost = skewed_cost()
    g = graph_of(cost.make_blocks())
    layer_mem = sum(cost.memory(b, horizon_tau) for b in g.layer_blocks(0))
    return DeviceNetwork.sample(n_devices, seed=seed,
                                mem_range=(1.0 * layer_mem, 1.5 * layer_mem),
                                bw_range=(0.05 * GBPS, 2 * GBPS),
                                compute_range=(20e9, 120e9))


class ObliviousExpertPolicy:
    """Dense-cost placement lifted onto the expert block graph: the inner
    policy sees head/proj/ffn blocks only; every expert of layer l rides
    on the layer's ffn device.  ``place`` returns expert-graph placements
    so the simulator prices it under the expert-level cost model."""

    aggregate_semantics = False
    name = "expert-oblivious"

    def __init__(self, expert_blocks, dense_blocks, dense_cost, **kw):
        self.expert_g = graph_of(expert_blocks)
        self.dense_g = graph_of(dense_blocks)
        self.inner = ALL_POLICIES["resource-aware"](dense_blocks,
                                                    dense_cost, **kw)
        self._prev_dense = None

    def place(self, net, tau, prev):
        dense = self.inner.place(net, tau, self._prev_dense)
        if dense is None:
            return None
        self._prev_dense = dense
        out = np.empty(len(self.expert_g.blocks), dtype=int)
        for l in range(self.expert_g.n_layers):
            for h_e, h_d in zip(self.expert_g.heads[l], self.dense_g.heads[l]):
                out[h_e.index] = dense[h_d.index]
            out[self.expert_g.proj[l].index] = dense[self.dense_g.proj[l].index]
            ffn_dev = dense[self.dense_g.ffn[l].index]
            for e in self.expert_g.experts[l]:
                out[e.index] = ffn_dev
        return out


class _RecordingPolicy:
    """Pass-through wrapper that remembers the last feasible placement so
    the benchmark can attribute the run's bottleneck resource afterward."""

    aggregate_semantics = False

    def __init__(self, inner):
        self._inner = inner
        self.name = inner.name
        self.last_place = None

    def place(self, net, tau, prev):
        p = self._inner.place(net, tau, prev)
        if p is not None:
            self.last_place = p
        return p


def run(n_tokens: int = N_TOKENS, seed: int = 0, sim_seed: int = 100,
        k: int = K_HEADLINE):
    """Simulated decode throughput under the skewed router load."""
    cost = skewed_cost()
    expert_blocks = cost.make_blocks()
    dense_cost = layered_cost()
    dense_blocks = dense_cost.make_blocks()
    out = {}
    for name in ("oblivious", "aware"):
        t0 = time.time()
        net = moe_net(seed=seed, horizon_tau=n_tokens + 50)
        if name == "oblivious":
            pol = ObliviousExpertPolicy(expert_blocks, dense_blocks,
                                        dense_cost,
                                        deadline=LAYERED_DEADLINE,
                                        pipeline_k=k)
        else:
            pol = ALL_POLICIES["resource-aware"](expert_blocks, cost,
                                                 deadline=LAYERED_DEADLINE,
                                                 pipeline_k=k)
        rec = _RecordingPolicy(pol)
        res = simulate(rec, expert_blocks, cost, net, n_tokens,
                       seed=sim_seed, pipeline_k=k)
        # attribute the final placement's bottleneck on the tau-0 net
        # (simulate copies the net, so `net` still holds nominal state)
        if rec.last_place is not None:
            kind, ident, busy = bottleneck_attribution(
                rec.last_place, expert_blocks, cost, net, n_tokens)
            bneck = f"dev{ident}" if kind == "device" \
                else f"link{ident[0]}-{ident[1]}"
        else:
            bneck, busy = "none", 0.0
        out[name] = dict(tok_s=n_tokens / res.total_latency,
                         migrations=res.migrations,
                         bneck=bneck, bneck_s=busy,
                         wall=time.time() - t0)
    return out


def run_engine(seed: int = 0) -> dict:
    """End-to-end roundtrip: reduced mixtral through the continuous
    engine; a straggler on the expert-heavy device forces an applied
    expert migration and the streams must stay bit-identical."""
    from repro.configs import get_config
    from repro.core import DeviceNetwork
    from repro.serving.engine import ServingEngine

    cfg = get_config("mixtral-8x7b").with_overrides(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab_size=97, sliding_window=64,
        dtype="float32", param_dtype="float32")
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 97, size=n) for n in (5, 11, 8, 14, 6)]

    def drive(lam, straggle_at):
        eng = ServingEngine(cfg, n_slots=2, max_seq=48, lam=lam, seed=0,
                            net=DeviceNetwork.sample(2, seed=1))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=10 + 3 * (i % 2))
        t0 = time.monotonic()
        while True:
            if straggle_at is not None and eng.decode_steps == straggle_at:
                place = eng.controller.place
                counts = np.zeros(eng.net.n_devices)
                for bl in eng.controller.blocks:
                    if bl.kind == "expert":
                        counts[int(place[bl.index])] += 1
                eng.net.inject_straggler(int(counts.argmax()),
                                         slowdown=500.0)
            if not eng.step():
                break
        return ({r.rid: r.out_tokens for r in eng.finished},
                time.monotonic() - t0, eng.migration_log)

    seq, _, _ = drive(10 ** 9, None)
    mig, wall, mlog = drive(3, straggle_at=4)
    applied = [e for e in mlog
               if e["expert_applied"] and e["n_expert_migrations"]]
    return {"streams_equal": seq == mig, "expert_applied": len(applied),
            "wall_s": wall}


def rows():
    out = run()
    base = out["oblivious"]["tok_s"]
    for name in ("oblivious", "aware"):
        d = out[name]
        extra = "" if name == "oblivious" else \
            f";x_oblivious={d['tok_s'] / base:.2f}"
        yield (f"moe_serving/skewed/{name}_K{K_HEADLINE}",
               d["wall"] * 1e6,
               f"tok_s={d['tok_s']:.2f}{extra};migr={d['migrations']};"
               f"bneck={d['bneck']};bneck_s={d['bneck_s']:.3g}")
    e = run_engine()
    # x_streams_equal is the row's deterministic claim (1.0 iff the
    # migrated stream is bit-identical): it carries the gate so the
    # compile-dominated roundtrip wall never does.
    yield ("moe_serving/engine_roundtrip", e["wall_s"] * 1e6,
           f"x_streams_equal={float(e['streams_equal']):.1f};"
           f"expert_applied={e['expert_applied']}")


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
