"""Shared configuration for the paper-reproduction benchmarks (§V.B).

Large LLM setup: h=32, D=2048, L0=64, GPT-2/LLaMA scale via the 32-layer
column lift (EXPERIMENTS.md §Reproduction notes), incremental decode
compute, λ=1 (the paper's worst-case migration stress).

The layered scenario axis (``layered_*``) swaps the column lift for the
true per-layer block graph (``layer_mode="graph"``): an 8-layer, 8-head
decoder on an 8-device edge cluster with heterogeneous link bandwidths
(0.05–2 Gbps) and per-device memory around ONE decoder layer's footprint
— the regime where placement granularity decides feasibility and
inter-layer hops are priced.
"""
from repro.core.blocks import CostModel, graph_of, make_blocks
from repro.core.network import DeviceNetwork, GB, GBPS

H = 32
D = 2048
L0 = 64
N_LAYERS = 32
DEADLINE = 0.2

LAYERED_H = 8
LAYERED_L = 8
LAYERED_DEADLINE = 0.5


def paper_cost(**over):
    kw = dict(d_model=D, n_heads=H, L0=L0, n_layers=N_LAYERS,
              compute_mode="incremental")
    kw.update(over)
    return CostModel(**kw)


def paper_blocks():
    return make_blocks(H)


def medium_net(seed=7, tight=False):
    mem = (1 * GB, 3 * GB) if tight else (2 * GB, 8 * GB)
    return DeviceNetwork.sample(25, seed=seed, mem_range=mem)


def layered_cost(**over):
    kw = dict(d_model=D, n_heads=LAYERED_H, L0=L0, n_layers=LAYERED_L,
              compute_mode="incremental", layer_mode="graph")
    kw.update(over)
    return CostModel(**kw)


def layered_blocks():
    return make_blocks(LAYERED_H, LAYERED_L)


def layered_net(seed=0, n_devices=8, horizon_tau=200):
    """Heterogeneous-bandwidth edge cluster sized so each device holds
    roughly one decoder layer (at the end-of-horizon KV footprint)."""
    cost = layered_cost()
    layer_mem = sum(cost.memory(b, horizon_tau)
                    for b in graph_of(layered_blocks()).layer_blocks(0))
    return DeviceNetwork.sample(n_devices, seed=seed,
                                mem_range=(1.0 * layer_mem, 1.5 * layer_mem),
                                bw_range=(0.05 * GBPS, 2 * GBPS),
                                compute_range=(20e9, 120e9))


def policy_kwargs(name):
    return dict(deadline=DEADLINE) if name in ("resource-aware", "static") \
        else {}
