"""Shared configuration for the paper-reproduction benchmarks (§V.B).

Large LLM setup: h=32, D=2048, L0=64, GPT-2/LLaMA scale via the 32-layer
column lift (EXPERIMENTS.md §Reproduction notes), incremental decode
compute, λ=1 (the paper's worst-case migration stress).
"""
from repro.core.blocks import CostModel, make_blocks
from repro.core.network import DeviceNetwork, GB

H = 32
D = 2048
L0 = 64
N_LAYERS = 32
DEADLINE = 0.2


def paper_cost(**over):
    kw = dict(d_model=D, n_heads=H, L0=L0, n_layers=N_LAYERS,
              compute_mode="incremental")
    kw.update(over)
    return CostModel(**kw)


def paper_blocks():
    return make_blocks(H)


def medium_net(seed=7, tight=False):
    mem = (1 * GB, 3 * GB) if tight else (2 * GB, 8 * GB)
    return DeviceNetwork.sample(25, seed=seed, mem_range=mem)


def policy_kwargs(name):
    return dict(deadline=DEADLINE) if name in ("resource-aware", "static") \
        else {}
