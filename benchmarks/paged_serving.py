"""Paged vs dense KV at a FIXED per-device memory budget (acceptance:
the paged engine admits >= 2x the dense engine's concurrent slots on a
mixed-length staggered-arrival workload, streaming bit-identical tokens).

Both engines get the same KV budget of 128 token-rows per kv head:

  dense  n_slots = 128 // max_seq            = 2 slots (worst-case rows)
  paged  kv_pages = 128 // page_size         = 16 pages, n_slots = 8

The dense engine must reserve ``max_seq`` rows per slot for the life of
the request, so the budget caps it at 2 resident requests no matter how
short they are.  The paged engine reserves only each request's OWN
horizon (prompt + decode budget, page-rounded — up to 3 pages here), so
the same bytes hold 5+ concurrent requests, and the workload drains in
fewer scheduler steps.  Streams are compared request-by-request and any
mismatch raises — memory savings never buy approximation.

    PYTHONPATH=src python benchmarks/paged_serving.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.serving_throughput import default_cfg
from repro.serving.engine import ServingEngine

MAX_SEQ = 64
PAGE_SIZE = 8
BUDGET_TOKENS = 128                      # KV rows per kv head, per engine


def make_workload(n_requests: int = 12, seed: int = 0):
    rng = np.random.default_rng(seed)
    lengths = [4, 6, 8, 10, 12]
    out = []
    for i in range(n_requests):
        prompt = rng.integers(0, 97, size=lengths[i % len(lengths)])
        out.append((prompt.astype(np.int32), 8 + int(rng.integers(0, 5)),
                    i // 2))
    return out


def drive(eng, workload, max_steps: int = 20_000) -> dict:
    """Feed staggered arrivals keyed on decode steps; track peak slot
    occupancy (the capacity the memory budget actually buys)."""
    pending = list(workload)
    peak = 0
    t0 = time.monotonic()
    while True:
        while pending and pending[0][2] <= eng.decode_steps:
            prompt, toks, _ = pending.pop(0)
            eng.submit(prompt, max_new_tokens=toks)
        progressed = eng.step()
        peak = max(peak, sum(s is not None for s in eng.slots))
        if not progressed:
            if pending:                  # idle: jump to the next arrival
                prompt, toks, _ = pending.pop(0)
                eng.submit(prompt, max_new_tokens=toks)
            else:
                break
        if eng.decode_steps >= max_steps:
            break
    wall = time.monotonic() - t0
    return {"streams": {r.rid: r.out_tokens for r in eng.finished},
            "peak_slots": peak, "decode_steps": eng.decode_steps,
            "tokens": sum(len(r.out_tokens) for r in eng.finished),
            "wall_s": wall}


def run(n_requests: int = 12, seed: int = 0, verbose: bool = True) -> dict:
    cfg = default_cfg()
    dense = ServingEngine(cfg, n_slots=BUDGET_TOKENS // MAX_SEQ,
                          max_seq=MAX_SEQ, lam=10 ** 9, seed=seed)
    paged = ServingEngine(cfg, n_slots=8, max_seq=MAX_SEQ, lam=10 ** 9,
                          seed=seed, paged=True, page_size=PAGE_SIZE,
                          kv_pages=BUDGET_TOKENS // PAGE_SIZE)
    out = {}
    for name, eng in (("dense", dense), ("paged", paged)):
        out[name] = drive(eng, make_workload(n_requests, seed))
        out[name]["engine"] = eng
    if out["paged"]["streams"] != out["dense"]["streams"]:
        raise RuntimeError("paged streams diverged from dense — paging "
                           "must be a pure memory re-layout")
    for a in paged.allocators:
        a.check_invariants()
        if a.live_pages:
            raise RuntimeError(f"page leak: {a.live_pages} live after "
                               f"drain")
    out["x_slots"] = out["paged"]["peak_slots"] / \
        max(out["dense"]["peak_slots"], 1)
    out["x_steps"] = out["dense"]["decode_steps"] / \
        max(out["paged"]["decode_steps"], 1)
    if verbose:
        print(f"{'engine':<8} {'peak slots':>10} {'steps':>7} "
              f"{'tokens':>7} {'wall_s':>7}")
        for name in ("dense", "paged"):
            d = out[name]
            print(f"{name:<8} {d['peak_slots']:>10} "
                  f"{d['decode_steps']:>7} {d['tokens']:>7} "
                  f"{d['wall_s']:>7.2f}")
        ok = out["x_slots"] >= 2.0
        print(f"\nconcurrency at equal KV budget: {out['x_slots']:.1f}x "
              f"({'PASS' if ok else 'FAIL'} >= 2x), "
              f"{out['x_steps']:.2f}x fewer scheduler steps")
    return out


def rows():
    """benchmarks.run driver hook (deterministic derived metrics gated)."""
    r = run(verbose=False)
    for name in ("dense", "paged"):
        d = r[name]
        us = d["wall_s"] / max(d["decode_steps"], 1) * 1e6
        yield (f"paged/{name}", us,
               f"peak_slots={d['peak_slots']};tokens={d['tokens']}")
    yield ("paged/capacity", 0.0,
           f"x_slots={r['x_slots']:.2f};x_steps={r['x_steps']:.2f}")


if __name__ == "__main__":
    run()
