"""Elastic serving under device churn: kill / slow / rejoin devices
mid-decode and measure what recovery costs.

The engine scenarios run the REAL paged serving engine on an 8-device
heterogeneous edge cluster (``paper_setup.layered_net`` links) under the
seeded workload driver, injecting churn through ``drive_virtual``'s
event hook at a moment the engine is provably mid-decode (asserted).
Churn rows use the flat per-step clock (one step = one time unit, like
``serving_load``); the ``elastic/priced`` row re-runs the churn-free
workload with ``price_by_model`` — steps priced by the controller's own
modeled per-token delay — and asserts the streams are unchanged by the
pricing.  The recovery accounting below is priced with the same modeled
delay.

Hard assertions (the bench RAISES, CI fails closed):
 - every churn scenario's surviving streams are BIT-IDENTICAL to the
   churn-free run — evacuation + teacher-forced replay must never change
   a token;
 - client-visible tokens lost to a failure stay ≤ the per-slot in-flight
   count at the failure (the engine's replay recovery loses zero);
 - evacuation recovers in fewer simulated steps than the restart
   baseline (below).

Restart baseline (``runtime.elastic.elastic_restore`` semantics): tear
down and re-provision EVERY placed block from the controller node's
checkpoint, then regenerate the in-flight tokens.  Priced with the same
cost model the evacuation plan is priced with: restore bytes transfer at
the controller->device link rates, regeneration pays the same decode
steps replay pays — but every in-flight token is re-emitted (client
visible), whereas evacuation moves only the dead device's blocks
peer-to-peer and replays with zero client-visible loss.
``x_restart_vs_evac`` (gated, higher is better) is the step ratio.

Simulator scenarios exercise the planning layers' churn on the paper's
layered topology: a device failure at τ=20 (placements must evacuate)
and a true mid-run ``join`` of a fresh strong device (the engine path is
rejoin-only — physical slot geometry is fixed at construction).

    PYTHONPATH=src python benchmarks/elastic_serving.py
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.paper_setup import (LAYERED_DEADLINE, layered_blocks,
                                    layered_cost, layered_net)
from repro.configs import get_config
from repro.core import ALL_POLICIES, simulate
from repro.serving.engine import ServingEngine
from repro.serving.workload import drive_virtual, make_workload, offered_load

MAX_SEQ = 64
PAGE_SIZE = 8
N_SLOTS = 4
LAM = 8                  # controller interval: active during the run
RATE = 0.25
HORIZON = 120.0
SEED = 11
KILL, SLOW_DEV = 5, 3    # non-controller devices (net.controller == 0)
T_CHURN, T_REJOIN = 25.0, 60.0
SIM_TOKENS, SIM_TAU = 60, 20


def elastic_cfg():
    """8 MHA heads so the head-position space tiles the 8-device cluster
    (one head per device: every failure loses live cache rows)."""
    return get_config("llama3-8b").with_overrides(
        n_layers=2, d_model=64, d_ff=128, n_heads=8, n_kv_heads=8,
        d_head=8, vocab_size=97, dtype="float32", param_dtype="float32")


def _engine(cfg):
    return ServingEngine(cfg, n_slots=N_SLOTS, max_seq=MAX_SEQ, lam=LAM,
                         seed=0, paged=True, page_size=PAGE_SIZE,
                         net=layered_net(seed=0, n_devices=8))


def _drive(cfg, reqs, events=None, priced=False):
    eng = _engine(cfg)
    t0 = time.monotonic()
    m = drive_virtual(eng, reqs, events=events, price_by_model=priced)
    wall = time.monotonic() - t0
    if m["n_finished"] != len(reqs):
        raise RuntimeError(f"elastic sweep must drain: "
                           f"{m['n_finished']}/{len(reqs)} finished")
    return eng, m, wall


def _restart_cost(eng, plan) -> float:
    """Restore-from-checkpoint bytes: every placed block re-transfers
    from the controller node, priced exactly like the migration delay
    (block bytes at τ-1 over the link rate, summed sequentially)."""
    net, cost = eng.net, eng.cost
    place = np.asarray(plan["place"])
    tau = max(int(plan["tau"]), 2)
    total = 0.0
    for b in eng.controller.blocks:
        j = int(place[b.index])
        rate = net.bandwidth[net.controller, j]
        if np.isfinite(rate):
            total += cost.memory(b, tau - 1) / rate
    return total


def _recovery_comparison(eng, fail_info) -> dict:
    """Evacuation-vs-restart accounting from the SAME failure snapshot."""
    plan, rec = fail_info["plan"], eng.recovery_log[0]
    step_delay = float(plan["d_pipe_est"])
    if not (np.isfinite(step_delay) and step_delay > 0):
        raise RuntimeError("post-evacuation placement has no finite "
                           "per-token delay — evacuation did not recover")
    evac_steps = math.ceil(plan["d_mig_est"] / step_delay) \
        + rec["replay_steps"]
    restart_steps = math.ceil(_restart_cost(eng, plan) / step_delay) \
        + rec["replay_steps"]     # restart regenerates the same tokens
    return {"evac_steps": evac_steps, "restart_steps": restart_steps,
            "tokens_lost": rec["tokens_lost"],
            "tokens_lost_restart": fail_info["inflight"],
            "replay_steps": rec["replay_steps"],
            "replayed_slots": rec["replayed_slots"],
            "x_restart_vs_evac": restart_steps / max(evac_steps, 1)}


def _sim_rows() -> list:
    """Planning-layer churn on the paper's layered topology."""
    blocks, cost = layered_blocks(), layered_cost()

    def run(events):
        pol = ALL_POLICIES["resource-aware"](blocks, cost,
                                             deadline=LAYERED_DEADLINE)
        net = layered_net(seed=0, horizon_tau=SIM_TOKENS + 50)
        t0 = time.monotonic()
        res = simulate(pol, blocks, cost, net, SIM_TOKENS, seed=100,
                       events=events)
        return res, time.monotonic() - t0

    base, base_wall = run(None)
    fail, fail_wall = run([(SIM_TAU, lambda net: net.fail(6))])
    if any(s.infeasible for s in fail.steps[SIM_TAU:]):
        raise RuntimeError("simulated failure left the policy infeasible "
                           "on the layered topology")

    def strong_join(net):
        net.join(float(net.mem_capacity.max()),
                 float(net.compute_max.max()),
                 np.full(net.n_devices,
                         float(np.median(net.bandwidth[
                             np.isfinite(net.bandwidth)]))))

    join, join_wall = run([(SIM_TAU, strong_join)])
    lat = {"churnfree": base, "fail": fail, "join": join}
    walls = {"churnfree": base_wall, "fail": fail_wall, "join": join_wall}
    out = []
    for name, res in lat.items():
        total = res.total_latency
        extra = ""
        if name != "churnfree":
            extra = f";lat_vs_churnfree={total / base.total_latency:.4f}"
        out.append((f"elastic/sim_{name}",
                    walls[name] / SIM_TOKENS * 1e6,
                    f"tok_s={SIM_TOKENS / total:.4f}{extra}"))
    return out


def run(verbose: bool = True) -> dict:
    cfg = elastic_cfg()
    reqs = make_workload(rate=RATE, horizon=HORIZON, seed=SEED,
                         vocab=cfg.vocab_size)
    off = offered_load(reqs, HORIZON)
    rows = []

    _, m0, wall0 = _drive(cfg, reqs)
    rows.append(("churnfree", m0, wall0, {}))

    # ---- fail: kill a device mid-decode, survive via evac + replay
    info: dict = {}

    def kill(eng):
        info["inflight"] = sum(len(eng.slots[s].out_tokens)
                               for s in eng._active())
        info["slots"] = len(eng._active())
        info["plan"] = eng.fail_device(KILL)

    eng, mf, wallf = _drive(cfg, reqs, events=[(T_CHURN, kill)])
    if not info["slots"]:
        raise RuntimeError("failure fired into an idle engine — the "
                           "scenario must kill a device MID-decode")
    if mf["streams"] != m0["streams"]:
        raise RuntimeError("surviving streams diverged after the failure "
                           "— recovery must be bit-identical")
    cmp = _recovery_comparison(eng, info)
    if cmp["tokens_lost"] > info["slots"]:
        raise RuntimeError(
            f"failure lost {cmp['tokens_lost']} client-visible tokens > "
            f"the {info['slots']}-slot in-flight bound")
    if not cmp["evac_steps"] < cmp["restart_steps"]:
        raise RuntimeError(
            f"evacuation ({cmp['evac_steps']} steps) must beat the "
            f"restart baseline ({cmp['restart_steps']} steps)")
    rows.append(("fail", mf, wallf, cmp))

    # ---- slow: persistent straggler, controller migrates away
    eng, ms, walls = _drive(
        cfg, reqs, events=[(T_CHURN,
                            lambda e: e.slow_device(SLOW_DEV, 8.0))])
    if ms["streams"] != m0["streams"]:
        raise RuntimeError("streams diverged under a slowdown — "
                           "migrations must be invariant")
    n_mig = sum(e["n_migrations"] for e in eng.migration_log)
    rows.append(("slow", ms, walls, {"n_migrations": n_mig}))

    # ---- rejoin: failure then the device returns (expansion plan)
    def rejoin(eng):
        eng.rejoin_device(KILL)

    eng, mr, wallr = _drive(cfg, reqs,
                            events=[(T_CHURN,
                                     lambda e: e.fail_device(KILL)),
                                    (T_REJOIN, rejoin)])
    if mr["streams"] != m0["streams"]:
        raise RuntimeError("streams diverged across fail+rejoin")
    if [r["event"] for r in eng.recovery_log] != ["fail", "rejoin"]:
        raise RuntimeError(f"unexpected recovery log: {eng.recovery_log}")
    rows.append(("rejoin", mr, wallr, {}))

    # ---- priced: model-delay step pricing must only re-time, not
    # re-token (satellite of the churn refactor: recovery costs can be
    # reported on the controller's own delay model)
    _, mp, wallp = _drive(cfg, reqs, priced=True)
    if mp["streams"] != m0["streams"]:
        raise RuntimeError("price_by_model changed a token stream — "
                           "pricing must be timing-only")
    rows.append(("priced", mp, wallp, {}))

    out = {"offered": off, "rows": rows, "sim": _sim_rows()}
    if verbose:
        print(f"{'row':<22} {'p50':>7} {'p95':>7} {'p99':>7} "
              f"{'goodput':>8}  extra")
        for name, m, _w, extra in rows:
            ex = ";".join(f"{k}={v}" for k, v in extra.items())
            print(f"elastic/{name:<14} {m['p50_ttft']:>7.2f} "
                  f"{m['p95_ttft']:>7.2f} {m['p99_ttft']:>7.2f} "
                  f"{m['goodput']:>8.4f}  {ex}")
        for name, _us, metrics in out["sim"]:
            print(f"{name:<22} {metrics}")
    return out


def rows():
    """benchmarks.run driver hook: virtual-clock latency percentiles and
    the recovery-step ratio are deterministic -> gated strictly;
    us_per_call is wall -> loose."""
    r = run(verbose=False)
    for name, m, wall, extra in r["rows"]:
        us = wall / max(m["steps"], 1) * 1e6
        s = (f"p50_ttft={m['p50_ttft']:.2f};p95_ttft={m['p95_ttft']:.2f};"
             f"p99_ttft={m['p99_ttft']:.2f};goodput={m['goodput']:.4f}")
        if "x_restart_vs_evac" in extra:
            s += (f";x_restart_vs_evac={extra['x_restart_vs_evac']:.3f};"
                  f"tokens_lost={extra['tokens_lost']};"
                  f"tokens_lost_restart={extra['tokens_lost_restart']};"
                  f"replay_steps={extra['replay_steps']}")
        if "n_migrations" in extra:
            s += f";n_migrations={extra['n_migrations']}"
        yield (f"elastic/{name}/r{RATE:g}", us, s)
    yield from r["sim"]


if __name__ == "__main__":
    run()
