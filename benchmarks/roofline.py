"""Deliverable (g) — roofline analysis from the dry-run artifacts.

Per (arch x shape x mesh) cell (results/dryrun/*.json):

  compute term    = HLO_FLOPs(per-device) / peak_FLOP/s          [s]
  memory term     = HLO_bytes(per-device) / HBM_bw               [s]
  collective term = collective_bytes(per-device) / ICI link bw   [s]

(post-SPMD cost_analysis and HLO shapes are already per-partition, so the
"/ chips" in the assignment's formulas is built in).  Also derived:

  MODEL_FLOPS   = 6·N·tokens (train) or 2·N_active·tokens (inference),
  useful ratio  = MODEL_FLOPS/chips / HLO_FLOPs  (remat/redundancy waste),
  roofline fraction = (MODEL_FLOPS/chips/peak) / max(terms)
                    — achieved useful-FLOP rate vs peak; the §Perf score.

Bottleneck notes name the lever that moves the dominant term (§Perf).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"

LEVERS = {
    "compute": "cut HLO FLOPs: causal-chunk skipping, capacity-factor, "
               "less remat recompute",
    "memory": "cut bytes: fuse, bf16 intermediates, int8 KV, smaller "
              "working set per layer",
    "collective": "cut bytes on ICI: reduce-scatter instead of all-gather, "
                  "int8 params/KV, overlap with compute, 2-pod DP",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per row


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    # trip-aware HLO-derived terms (hlo_analysis.full_analysis); the raw
    # cost_analysis numbers are loop-body-once on the CPU backend (verified)
    # and kept in the artifact only for reference.
    flops = rec.get("dot_flops", rec.get("flops", 0.0))
    byts = rec.get("hbm_bytes", rec.get("bytes_accessed", 0.0))
    coll = sum(rec["collective_bytes"].values())
    t_c = flops / PEAK_FLOPS_BF16
    t_m = byts / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    useful = mf / flops if flops else 0.0
    mem = rec.get("memory_analysis", {})
    args = mem.get("argument_size_in_bytes", 0)
    # roofline fraction = essential step time / modeled step time, where
    # essential = max(useful FLOPs at peak, one read of the resident state)
    # — i.e. how close the compiled program is to the *achievable* roofline
    # for its dominant resource.  (A pure peak-FLOPs fraction would score
    # decode — inherently memory-bound — near 0 by construction.)
    essential = max(mf / PEAK_FLOPS_BF16, args / HBM_BW)
    frac = essential / max(max(terms.values()), 1e-30)
    frac_peak = (mf / PEAK_FLOPS_BF16) / max(max(terms.values()), 1e-30)
    return {
        "cell": rec["cell"], "arch": rec["arch"], "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "memory_floor_s": args / HBM_BW,
        "dominant": dominant,
        "model_flops_per_chip": mf, "hlo_flops": flops,
        "useful_ratio": useful, "roofline_fraction": frac,
        "frac_peak_flops": frac_peak,
        "args_gib": args / 2 ** 30,
        "temp_gib": mem.get("temp_size_in_bytes", 0) / 2 ** 30,
        "lever": LEVERS[dominant],
    }


def load_all(results_dir: Path = RESULTS_DIR, mesh: str = "16x16",
             tag: str = "") -> List[dict]:
    out = []
    for p in sorted(results_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh:
            continue
        if tag and not rec["cell"].endswith(f"__{tag}"):
            continue
        if not tag and rec["cell"].count("__") > 2:
            continue  # tagged perf-iteration artifacts
        r = analyze_record(rec)
        if r:
            out.append(r)
    return out


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "useful | roofline frac |\n|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        body += (f"| {r['arch']}/{r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.3f} |\n")
    return hdr + body


def paged_kernel_rows():
    """Analytic HBM rooflines for the paged decode-attention kernel —
    deterministic (no dry-run artifacts, no wall clock), so the derived
    ``x_`` ratios are strict-gated by --check.  Decode attention is
    memory-bound: step time = KV bytes read / HBM_BW.  The dense kernel
    reads the reserved max_seq extent; the paged kernel reads only live
    pages (live tokens rounded up to the page size), so the ratio is
    extent / page-rounded-live — the PR-7 claim, priced at the roofline."""
    B, KvE, dh, P = 8, 8, 128, 64          # llama-70b-ish decode shapes
    bytes_per_tok = 2 * KvE * dh * 2       # K+V, bf16
    for max_seq, live in ((8192, 1500), (8192, 4096)):
        dense_us = B * max_seq * bytes_per_tok / HBM_BW * 1e6
        paged_tok = -(-live // P) * P
        paged_us = B * paged_tok * bytes_per_tok / HBM_BW * 1e6
        frac = live / paged_tok
        yield (f"roofline/paged_decode/extent{max_seq}_live{live}",
               paged_us,
               f"x_dense_extent={dense_us / paged_us:.3f};"
               f"page_util={frac:.3f};dense_us={dense_us:.1f}")


def rows():
    table = load_all()
    for r in table:
        step_bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        yield (f"roofline/{r['arch']}/{r['shape']}", step_bound * 1e6,
               f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
               f"useful={r['useful_ratio']:.2f}")
    yield from paged_kernel_rows()


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
