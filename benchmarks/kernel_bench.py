"""Kernel micro-benchmarks: wall time of the pure-jnp oracle at model-like
shapes (CPU wall time is NOT a TPU projection — the TPU-side statement is
the roofline bytes/FLOPs, computed here analytically per kernel) and an
interpret-mode allclose gate."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_kernel import rwkv6_chunked
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, reps=5):
    """Min-of-reps wall time (us): the minimum is the standard
    noise-robust statistic for micro-benches — scheduler preemption and
    cache pollution only ever ADD time, so the min tracks the true cost
    and keeps the --check regression gate from flapping."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def bench_flash(B=1, H=8, KvE=8, S=1024, dh=128):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KvE, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KvE, S, dh), jnp.float32)
    us = _time(lambda *a: ref.flash_attention_ref(*a), q, k, v)
    out = flash_attention(q, k, v, bq=256, bk=256, interpret=True)
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v)).max())
    flops = 4 * B * H * S * S * dh / 2  # causal
    tpu_us = flops / PEAK_FLOPS_BF16 * 1e6
    return us, f"allclose_err={err:.1e};tpu_roofline_us={tpu_us:.1f}"


def bench_decode(B=8, H=8, KvE=8, T=8192, dh=128):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KvE, T, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KvE, T, dh), jnp.float32)
    lens = jnp.full((B,), T, jnp.int32)
    us = _time(lambda *a: ref.decode_attention_ref(*a), q, k, v, lens)
    sm = decode_attention(q[:2, :, :], k[:2, :, :256], v[:2, :, :256],
                          lens[:2] * 0 + 256, bk=128, interpret=True)
    err = float(jnp.abs(sm - ref.decode_attention_ref(
        q[:2], k[:2, :, :256], v[:2, :, :256], lens[:2] * 0 + 256)).max())
    hbm_bytes = 2 * B * KvE * T * dh * 2  # K+V read, bf16 on TPU
    tpu_us = hbm_bytes / HBM_BW * 1e6
    return us, f"allclose_err={err:.1e};tpu_membound_us={tpu_us:.1f}"


def bench_rwkv6(B=1, H=8, S=512, dh=64):
    ks = jax.random.split(KEY, 5)
    mk = lambda i: 0.3 * jax.random.normal(ks[i], (B, H, S, dh))
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, H, S, dh))) * 0.4 + 0.55
    u = 0.1 * jax.random.normal(ks[4], (H, dh))
    s0 = jnp.zeros((B, H, dh, dh))
    us = _time(lambda *a: ref.rwkv6_ref(*a)[0], r, k, v, w, u, s0)
    y, _ = rwkv6_chunked(r[:, :, :64], k[:, :, :64], v[:, :, :64],
                         w[:, :, :64], u, s0, chunk=32, interpret=True)
    yr, _ = ref.rwkv6_ref(r[:, :, :64], k[:, :, :64], v[:, :, :64],
                          w[:, :, :64], u, s0)
    err = float(jnp.abs(y - yr).max())
    hbm = 4 * B * H * S * dh * 2 + B * H * S * dh * 4
    tpu_us = hbm / HBM_BW * 1e6
    return us, f"allclose_err={err:.1e};tpu_membound_us={tpu_us:.1f}"


def bench_kernel_decode(B=4, H=8, KvE=4, T=512, dh=32, bk=128):
    """Placement-driven dispatch vs padded-to-global-H dispatch on a
    SKEWED per-layer placement (interpret mode, so wall time tracks grid
    work — the TPU statement is the same: grid rows = DMA'd KV blocks).

    Padded: every slot's kernel runs the full (B, H, nk) grid because its
    shape came from the config; resident: slot s runs (B, H_res(l, s), nk)
    over exactly the rows the BlockGraph placement put there — on the
    skewed split most slots do 1/8 of the padded work."""
    from repro.core.blocks import graph_of, make_blocks
    from repro.core.placement_bridge import placement_to_head_slices
    from repro.kernels.decode_attention import decode_attention_resident

    splits = [(5, 1, 1, 1), (1, 1, 5, 1)]     # ragged per-layer head counts
    n_slots, n_layers = len(splits[0]), len(splits)
    blocks = make_blocks(H, n_layers)
    g = graph_of(blocks)
    place = np.zeros(len(blocks), dtype=int)
    for l, split in enumerate(splits):
        hid = 0
        for s, cnt in enumerate(split):
            for _ in range(cnt):
                place[g.heads[l][hid].index] = s
                hid += 1
    slices = placement_to_head_slices(place, blocks, n_slots)

    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KvE, T, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KvE, T, dh), jnp.float32)
    lens = jnp.full((B,), T, jnp.int32)
    all_rows = jnp.arange(H, dtype=jnp.int32)

    def padded_pass():
        outs = []
        for l in range(n_layers):
            for s in range(n_slots):
                out = decode_attention_resident(q, k, v, lens, all_rows,
                                                bk=bk, interpret=True)
                outs.append(out[:, slices[l][s]])   # discard non-resident
        return outs

    def resident_pass():
        outs = []
        for l in range(n_layers):
            for s in range(n_slots):
                rows = jnp.asarray(slices[l][s])
                outs.append(decode_attention_resident(
                    q, k, v, lens, rows, bk=bk, interpret=True))
        return outs

    us_pad = _time(padded_pass)
    us_res = _time(resident_pass)
    want = ref.decode_attention_ref(q, k, v, lens)
    err = 0.0
    for (l, s), out in zip(((l, s) for l in range(n_layers)
                           for s in range(n_slots)), resident_pass()):
        sl = slices[l][s]
        if len(sl):
            err = max(err, float(jnp.abs(out - want[:, sl]).max()))
    grid_pad = n_layers * n_slots * H
    grid_res = sum(len(s) for per in slices for s in per)
    return (us_pad, us_res,
            f"grid_rows={grid_pad}",
            f"allclose_err={err:.1e};grid_rows={grid_res};"
            f"x_padded={us_pad / us_res:.2f}")


def bench_kernel_decode_paged(B=4, H=8, KvE=4, T=512, dh=32, P=64,
                              live_tokens=192):
    """Paged block-sparse dispatch vs the dense max_seq extent (PR-7
    kernels, interpret mode).  Dense: the resident kernel walks every
    T/bk KV block of the reserved extent; paged: the paged kernel's grid
    is ``ceil(live/P)`` live pages per slot — the structural claim is the
    grid/DMA ratio, the wall ratio (``x_padded``, wall-tolerance-gated)
    tracks it on CPU."""
    from repro.kernels.decode_attention import (
        decode_attention_int8_paged_resident, decode_attention_paged_resident,
        decode_attention_resident)

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, KvE, T, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, KvE, T, dh), jnp.float32)
    lens = jnp.full((B,), live_tokens, jnp.int32)
    rows_all = jnp.arange(H, dtype=jnp.int32)

    # pooled page store: slot b's logical page ip lives at physical page
    # b·np_live + ip (a convenient dense packing; any id layout works)
    np_total, np_live = T // P, -(-live_tokens // P)
    k_pages = k.reshape(B, KvE, np_total, P, dh)[:, :, :np_live] \
        .transpose(0, 2, 1, 3, 4).reshape(B * np_live, KvE, P, dh)
    v_pages = v.reshape(B, KvE, np_total, P, dh)[:, :, :np_live] \
        .transpose(0, 2, 1, 3, 4).reshape(B * np_live, KvE, P, dh)
    page_map = (jnp.arange(B)[:, None] * np_live
                + jnp.arange(np_live)[None, :]).astype(jnp.int32)

    def dense_pass():
        return decode_attention_resident(q, k, v, lens, rows_all, bk=P,
                                         interpret=True)

    def paged_pass():
        return decode_attention_paged_resident(q, k_pages, v_pages, lens,
                                               page_map, rows_all,
                                               interpret=True)

    us_dense = _time(dense_pass)
    us_paged = _time(paged_pass)
    want = ref.decode_attention_ref(q, k, v, lens)
    err = float(jnp.abs(paged_pass() - want).max())
    # int8 page store: per-(token, head) scales page with their values
    amax = jnp.max(jnp.abs(k_pages), axis=-1, keepdims=True)
    k_sc = jnp.maximum(amax / 127.0, 1e-8)
    k_q8 = jnp.clip(jnp.round(k_pages / k_sc), -127, 127).astype(jnp.int8)
    amax = jnp.max(jnp.abs(v_pages), axis=-1, keepdims=True)
    v_sc = jnp.maximum(amax / 127.0, 1e-8)
    v_q8 = jnp.clip(jnp.round(v_pages / v_sc), -127, 127).astype(jnp.int8)

    def paged_i8_pass():
        return decode_attention_int8_paged_resident(
            q, k_q8, k_sc[..., 0][..., None], v_q8, v_sc[..., 0][..., None],
            lens, page_map, rows_all, interpret=True)

    us_i8 = _time(paged_i8_pass)
    err_i8 = float(jnp.abs(paged_i8_pass() - want).max())
    blocks_dense = B * H * np_total
    blocks_paged = B * H * np_live
    return [
        ("kernel_decode/paged_dense_extent", us_dense,
         f"kv_blocks={blocks_dense}"),
        ("kernel_decode/paged_resident_live", us_paged,
         f"allclose_err={err:.1e};kv_blocks={blocks_paged};"
         f"x_padded={us_dense / us_paged:.2f}"),
        ("kernel_decode/paged_resident_int8", us_i8,
         f"allclose_err={err_i8:.1e};kv_blocks={blocks_paged}"),
    ]


def kernel_decode_rows():
    us_pad, us_res, d_pad, d_res = bench_kernel_decode()
    yield ("kernel_decode/padded_global_H", us_pad, d_pad)
    yield ("kernel_decode/resident_slice", us_res, d_res)
    yield from bench_kernel_decode_paged()


def rows():
    us, d = bench_flash()
    yield ("kernel/flash_attention_ref", us, d)
    us, d = bench_decode()
    yield ("kernel/decode_attention_ref", us, d)
    us, d = bench_rwkv6()
    yield ("kernel/rwkv6_ref", us, d)


if __name__ == "__main__":
    for r in rows():
        print(",".join(map(str, r)))
