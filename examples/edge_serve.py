"""End-to-end driver (the paper's kind = inference): continuous-batching
serving with the resource-aware controller migrating attention heads away
from an injected straggler, live — with mixed prompt lengths in one batch
and freed slots re-admitted mid-stream.

    PYTHONPATH=src python examples/edge_serve.py
"""
import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingEngine

# musicgen-large reduced (MHA layout => per-head physical migration applies)
cfg = get_config("musicgen-large").with_overrides(
    n_layers=3, d_model=128, d_ff=512, n_heads=8, n_kv_heads=8, d_head=16,
    vocab_size=512, dtype="float32", param_dtype="float32")

# controller prices placements at PRODUCTION width (full musicgen-large
# d_model) over the per-layer block graph of the served model's 3 layers —
# one head permutation per layer
engine = ServingEngine(cfg, n_slots=4, max_seq=96, lam=6,
                       cost_cfg=get_config("musicgen-large"))
print(f"engine: {engine.net.n_devices} slots, "
      f"{cfg.n_heads} heads, controller interval λ={engine.lam}, "
      f"prefill buckets {engine.buckets}")

rng = np.random.default_rng(0)
# phase 1: healthy cluster — mixed prompt lengths share one batch while
# the controller settles a placement
for i, L in enumerate((6, 12, 9, 17)):
    engine.submit(rng.integers(0, cfg.vocab_size, size=L),
                  max_new_tokens=18 + 4 * (i % 2))
engine.run()
counts = engine.controller.head_counts()   # heads/device over ALL layers
busiest = int(counts.argmax())
before = int(counts[busiest])

# phase 2: the busiest slot becomes a 25x straggler mid-service —
# the paper's C_j(τ) drop; Algorithm 1 must MIGRATE heads away, permuting
# a KV cache whose slots sit at different sequence positions
engine.net.inject_straggler(busiest, slowdown=25.0)
print(f"injected 25x straggler on slot {busiest} "
      f"(holding {before} heads)")
for L in (8, 15, 11, 20):
    engine.submit(rng.integers(0, cfg.vocab_size, size=L),
                  max_new_tokens=24)
done = engine.run()

print(f"\nserved {len(done)} requests, {engine.decode_steps} decode steps")
util = engine.slot_busy_steps / max(engine.decode_steps * engine.n_slots, 1)
print(f"slot utilization {util:.0%}, "
      f"prefill compiles bounded to buckets {sorted(engine.prefill_buckets_used)}")
migr = sum(m['n_migrations'] for m in engine.migration_log)
print(f"controller ran {len(engine.migration_log)} intervals, "
      f"migrated {migr} head-blocks")
after = int(engine.controller.head_counts()[busiest])
print(f"heads on straggler slot {busiest}: {before} -> {after}")
for r in done[:4]:
    print(f"  req {r.rid}: {len(r.out_tokens)} tokens, "
          f"latency {r.t_done - r.t_submit:.2f}s")
