"""Quickstart: build a model from the public API, train a few steps on the
synthetic pipeline, checkpoint, and generate tokens with the KV cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models.api import build_model
from repro.optim.adamw import AdamW

# 1) config: any --arch id works; reduce it for the CPU demo
cfg = get_config("llama3-8b").with_overrides(
    n_layers=2, d_model=128, d_ff=512, n_heads=8, n_kv_heads=4, d_head=16,
    vocab_size=512, dtype="float32", param_dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
print(f"model: {cfg.name}-reduced, "
      f"{sum(x.size for x in jax.tree.leaves(params))/1e3:.0f}K params")

# 2) a few training steps
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)


@jax.jit
def train_step(params, opt_state, batch):
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    new_params, new_opt = opt.update(grads, opt_state, params)
    return new_params, new_opt, loss


src = iter(SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0))
for i in range(10):
    params, opt_state, loss = train_step(
        params, opt_state, {k: jnp.asarray(v) for k, v in next(src).items()})
    if i % 3 == 0:
        print(f"step {i}: loss {float(loss):.3f}")

# 3) autoregressive generation through the cache path
prompt = jnp.arange(8, dtype=jnp.int32)[None, :]
state = model.init_decode_state(params, batch=1, max_seq=32)
# donate the state: the KV cache updates in place instead of allocating
# a second cache every step (repro.analysis lint RPR005 enforces this)
logits, state = jax.jit(model.prefill, donate_argnums=(1,))(
    params, state, prompt)
decode = jax.jit(model.decode_step, donate_argnums=(1,))
out = []
tok = jnp.argmax(logits, -1)
for _ in range(12):
    out.append(int(tok[0]))
    logits, state = decode(params, state, tok)
    tok = jnp.argmax(logits, -1)
print("generated:", out)
