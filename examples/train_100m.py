"""Train a ~100M-parameter llama-family model on the synthetic pipeline
with checkpoint/resume — the training end-to-end driver.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

(~100M params: 12 layers x d_model 768 + 32k vocab. A few hundred steps on
this CPU container takes tens of minutes; --steps 30 demos the loop.)
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()
    train_main([
        "--arch", "llama3-8b", "--reduced",
        "--d-model", "768", "--n-layers", "12",
        "--steps", str(args.steps), "--batch", "4", "--seq", "256",
        "--ckpt", "/tmp/repro_100m_ckpt", "--ckpt-every", "50",
        "--log-every", "5",
    ])
