"""Reproduce the paper's headline comparison in one minute: 25-device
medium-scale simulation, resource-aware vs EdgeShard vs Galaxy (Fig. 3/4
regime), printing per-policy latency/memory and the migration trace.

    PYTHONPATH=src python examples/migration_demo.py
"""

from repro.core import ALL_POLICIES, DeviceNetwork, simulate
from repro.core.blocks import CostModel, make_blocks
from repro.core.network import GB

blocks = make_blocks(32)
cost = CostModel(d_model=2048, n_heads=32, L0=64, n_layers=32,
                 compute_mode="incremental")
net = DeviceNetwork.sample(25, seed=7, mem_range=(1 * GB, 3 * GB))
N = 300

print(f"{'policy':16s} {'total[s]':>9s} {'last-step[s]':>12s} "
      f"{'max-dev-mem[GB]':>15s} {'migrations':>10s}")
results = {}
for name in ("resource-aware", "static", "galaxy", "edgeshard",
             "greedy", "round-robin"):
    kw = dict(deadline=0.2) if name in ("resource-aware", "static") else {}
    pol = ALL_POLICIES[name](blocks, cost, **kw)
    res = simulate(pol, blocks, cost, net, N, seed=11)
    results[name] = res
    print(f"{name:16s} {res.total_latency:9.1f} "
          f"{res.per_step_latency[-1]:12.4f} "
          f"{res.mem_max_series[-1]/2**30:15.2f} {res.migrations:10d}")

ra = results["resource-aware"].total_latency
print("\nspeedups vs resource-aware:")
for name, res in results.items():
    if name != "resource-aware":
        print(f"  {name:14s} {res.total_latency / ra:5.2f}x slower")
